//! Plan caches (paper §5 "responsive execution").
//!
//! [`PlanCache`] is the per-job cache: plans are indexed by the quantised
//! [`crate::model::InputKey`] — a two-axis [`SizeKey`] whose secondary axis
//! is 0 for single-axis workloads; similar input sizes (within a relative
//! tolerance, per axis) share a plan — "the memory usages of similar input
//! sizes are similar, and the generated plans are also similar. Therefore,
//! they can also be the plans of each other." It can be bounded: under an
//! adversarial input-size stream (every mini-batch a new quantisation cell)
//! an unbounded cache grows forever, so a configurable capacity evicts the
//! least-recently-hit entry.
//!
//! [`SharedPlanCache`] is the fleet-level cache: entries are scoped by a
//! *model signature* (architecture + batch) and the planning budget, so
//! identical-architecture tenants in a multi-job fleet reuse each other's
//! plans. Reuse is conservative: a plan generated under an equal-or-tighter
//! budget checkpoints at least as much as one planned for a larger budget,
//! so serving it to a tenant with more memory is always safe (merely
//! sub-optimal); the nearest (largest qualifying) budget wins.

use super::Plan;
use crate::config::ModelSpec;
use crate::obs;
use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// Quantised input-size key: (primary axis, secondary axis). Single-axis
/// workloads use secondary 0, making every pre-graph cache behaviour a
/// special case of the two-axis one.
pub type SizeKey = (u64, u64);

#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped by the capacity bound (least-recently-hit first).
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Recency bookkeeping shared by both plan caches: a monotonic clock, a
/// key -> stamp map, and the stamp -> key inverse (stamps are unique, so
/// the first `by_stamp` entry is always the least-recently-hit key).
#[derive(Clone, Debug)]
struct LruIndex<K: Ord + Copy> {
    recency: BTreeMap<K, u64>,
    by_stamp: BTreeMap<u64, K>,
    clock: u64,
}

impl<K: Ord + Copy> LruIndex<K> {
    fn new() -> Self {
        LruIndex { recency: BTreeMap::new(), by_stamp: BTreeMap::new(), clock: 0 }
    }

    /// Mark `key` most-recent (on hit and on insert).
    fn touch(&mut self, key: K) {
        self.clock += 1;
        if let Some(old) = self.recency.insert(key, self.clock) {
            self.by_stamp.remove(&old);
        }
        self.by_stamp.insert(self.clock, key);
    }

    /// Drop and return the least-recently-hit key.
    fn pop_lru(&mut self) -> Option<K> {
        if let Some((&stamp, &victim)) = self.by_stamp.iter().next() {
            self.by_stamp.remove(&stamp);
            self.recency.remove(&victim);
            Some(victim)
        } else {
            None
        }
    }

    /// Forget one key (no-op if untracked).
    fn remove(&mut self, key: &K) {
        if let Some(stamp) = self.recency.remove(key) {
            self.by_stamp.remove(&stamp);
        }
    }

    fn clear(&mut self) {
        self.recency.clear();
        self.by_stamp.clear();
    }
}

/// Within relative tolerance on one axis; a zero key only matches zero
/// (a 1-D entry never serves a 2-D probe and vice versa).
fn axis_near(key: u64, probe: u64, tol: f64) -> bool {
    key.abs_diff(probe) <= (probe as f64 * tol) as u64
}

/// Input-size-indexed plan cache with relative-tolerance matching and an
/// optional capacity (0 = unbounded) with least-recently-hit eviction.
#[derive(Clone, Debug)]
pub struct PlanCache {
    plans: BTreeMap<SizeKey, Plan>,
    lru: LruIndex<SizeKey>,
    capacity: usize,
    tolerance: f64,
    stats: CacheStats,
}

impl PlanCache {
    /// Unbounded cache (the single-job default).
    pub fn new(tolerance: f64) -> Self {
        Self::with_capacity(tolerance, 0)
    }

    /// Bounded cache: at most `capacity` entries (0 = unbounded); inserting
    /// beyond it evicts the least-recently-hit entry.
    pub fn with_capacity(tolerance: f64, capacity: usize) -> Self {
        PlanCache {
            plans: BTreeMap::new(),
            lru: LruIndex::new(),
            capacity,
            tolerance,
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// Look up a plan for a (primary, secondary) input size, accepting any
    /// entry within ±tolerance (relative) on *each* axis independently —
    /// a near-match on the source length never excuses a far-off target
    /// length. Nearest key (primary distance, then secondary) wins.
    pub fn lookup(&mut self, key: SizeKey) -> Option<Plan> {
        let (p, s) = key;
        let ptol = (p as f64 * self.tolerance) as u64;
        let lo = (p.saturating_sub(ptol), 0u64);
        let hi = (p.saturating_add(ptol), u64::MAX);
        let best = self
            .plans
            .range(lo..=hi)
            .filter(|((_, ks), _)| axis_near(*ks, s, self.tolerance))
            .min_by_key(|((kp, ks), _)| (kp.abs_diff(p), ks.abs_diff(s)))
            .map(|(k, plan)| (*k, plan.clone()));
        match best {
            Some((k, plan)) => {
                self.stats.hits += 1;
                obs::inc("plan_cache.hits");
                self.lru.touch(k);
                Some(plan)
            }
            None => {
                self.stats.misses += 1;
                obs::inc("plan_cache.misses");
                None
            }
        }
    }

    /// 1-D convenience over [`PlanCache::lookup`] (secondary axis 0).
    pub fn lookup1(&mut self, input_size: u64) -> Option<Plan> {
        self.lookup((input_size, 0))
    }

    /// Non-mutating exact-key probe: no stats, no LRU touch. The
    /// cohort-parallel planner peeks with this; the serial `lookup_exact`
    /// still runs (and still counts its miss) when the iteration begins.
    pub fn contains(&self, key: SizeKey) -> bool {
        self.plans.contains_key(&key)
    }

    /// Exact-key lookup (used with pre-quantised plan sizes).
    pub fn lookup_exact(&mut self, key: SizeKey) -> Option<Plan> {
        match self.plans.get(&key).cloned() {
            Some(p) => {
                self.stats.hits += 1;
                obs::inc("plan_cache.hits");
                self.lru.touch(key);
                Some(p)
            }
            None => {
                self.stats.misses += 1;
                obs::inc("plan_cache.misses");
                None
            }
        }
    }

    pub fn insert(&mut self, key: SizeKey, plan: Plan) {
        let novel = !self.plans.contains_key(&key);
        if novel && self.capacity > 0 && self.plans.len() >= self.capacity {
            if let Some(victim) = self.lru.pop_lru() {
                self.plans.remove(&victim);
                self.stats.evictions += 1;
                obs::inc("plan_cache.evictions");
            }
        }
        self.plans.insert(key, plan);
        self.lru.touch(key);
    }

    /// Invalidate everything (e.g. budget changed). Stats survive.
    pub fn clear(&mut self) {
        if !self.plans.is_empty() {
            obs::inc("plan_cache.purges");
        }
        self.plans.clear();
        self.lru.clear();
    }
}

// ---------------------------------------------------------------------------
// Cross-job shared cache (fleet)
// ---------------------------------------------------------------------------

/// FNV-1a over the architecture fields, batch size, and the task's
/// activation-widening factor (XLNet-style two-stream attention changes
/// per-layer residual bytes without changing the `ModelSpec`). Two jobs
/// with equal signatures plan over identical per-layer shapes for any given
/// input size, so their plans are interchangeable (budget permitting).
pub fn model_signature(spec: &ModelSpec, batch: usize, act_factor: f64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for byte in v.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    eat(spec.vocab as u64);
    eat(spec.hidden as u64);
    eat(spec.layers as u64);
    eat(spec.decoder_layers as u64);
    eat(spec.heads as u64);
    eat(spec.ffn as u64);
    eat(spec.max_seq as u64);
    eat(batch as u64);
    eat(act_factor.to_bits());
    h
}

type SharedKey = (u64, u64, u64, u64); // (signature, primary, secondary, budget)

/// Fleet-wide plan cache keyed by (model signature, quantised input key,
/// budget), bounded with least-recently-hit eviction like [`PlanCache`].
#[derive(Debug)]
pub struct SharedPlanCache {
    entries: BTreeMap<SharedKey, Plan>,
    lru: LruIndex<SharedKey>,
    capacity: usize,
    stats: CacheStats,
}

/// Handle the fleet hands each job's Coordinator (single-threaded engines;
/// borrows are confined to one lookup/insert at a time).
pub type SharedCacheHandle = Rc<RefCell<SharedPlanCache>>;

/// Build a shareable cache handle (`capacity` 0 = unbounded).
pub fn shared_plan_cache(capacity: usize) -> SharedCacheHandle {
    Rc::new(RefCell::new(SharedPlanCache::new(capacity)))
}

impl SharedPlanCache {
    pub fn new(capacity: usize) -> Self {
        SharedPlanCache {
            entries: BTreeMap::new(),
            lru: LruIndex::new(),
            capacity,
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Find a reusable plan for `(signature, size)` under `budget`: any
    /// entry planned with a budget `<= budget` is conservative (checkpoints
    /// at least as much), so it is safe for this tenant; the largest
    /// qualifying budget (least conservative) wins.
    pub fn lookup(&mut self, signature: u64, size: SizeKey, budget: u64) -> Option<Plan> {
        let lo = (signature, size.0, size.1, 0u64);
        let hi = (signature, size.0, size.1, budget);
        let found = self
            .entries
            .range(lo..=hi)
            .next_back()
            .map(|(k, p)| (*k, p.clone()));
        match found {
            Some((k, p)) => {
                self.stats.hits += 1;
                obs::inc("shared_cache.hits");
                self.lru.touch(k);
                Some(p)
            }
            None => {
                self.stats.misses += 1;
                obs::inc("shared_cache.misses");
                None
            }
        }
    }

    /// Non-mutating probe: would [`Self::lookup`] hit? No stats, no LRU
    /// touch — the cohort-parallel planner uses this to decide which
    /// tenants need a fresh plan WITHOUT perturbing cache state (the
    /// real lookup still runs, and still misses, on the serial path).
    pub fn peek(&self, signature: u64, size: SizeKey, budget: u64) -> bool {
        let lo = (signature, size.0, size.1, 0u64);
        let hi = (signature, size.0, size.1, budget);
        self.entries.range(lo..=hi).next_back().is_some()
    }

    /// Does the cache hold ANY entry for this model signature, at any input
    /// size or budget? Non-mutating (no stats, no LRU touch) — the fleet's
    /// plan-cache-warm placement uses this to prefer the device whose cache
    /// a new tenant's architecture has already seeded.
    pub fn holds_signature(&self, signature: u64) -> bool {
        let lo = (signature, 0u64, 0u64, 0u64);
        let hi = (signature, u64::MAX, u64::MAX, u64::MAX);
        self.entries.range(lo..=hi).next().is_some()
    }

    /// Copy every entry of `other` into this cache (capacity and LRU rules
    /// apply per insert; existing cells are overwritten). The multi-device
    /// fleet merges its per-device caches through this before persisting
    /// one on-disk artifact.
    pub fn absorb(&mut self, other: &SharedPlanCache) {
        for (&(sig, p, s, budget), plan) in &other.entries {
            self.insert(sig, (p, s), budget, plan.clone());
        }
    }

    /// Warm-start lookup: the exact cell first; otherwise the smallest
    /// entry that *dominates* the probe on both size axes (primary ≥,
    /// secondary ≥) under a qualifying budget. A plan generated for a
    /// larger input at an equal-or-tighter budget checkpoints at least as
    /// much as this input needs, so it is safe (merely conservative) —
    /// the same monotonicity the coordinator's quantise-UP rule rests on.
    /// This is what lets a restarted fleet serve its very first draws from
    /// a disk-loaded cache even when early keys only recurred in larger
    /// quantisation cells.
    pub fn lookup_dominating(&mut self, signature: u64, size: SizeKey, budget: u64) -> Option<Plan> {
        if self.peek(signature, size, budget) {
            return self.lookup(signature, size, budget); // counts the hit
        }
        // ascending scan from the probe: the first (primary, secondary)
        // group dominating the probe with any qualifying budget wins; within
        // the group the largest budget ≤ ours is the least conservative
        let lo = (signature, size.0, size.1, 0u64);
        let hi = (signature, u64::MAX, u64::MAX, u64::MAX);
        let mut best: Option<SharedKey> = None;
        for (&k, _) in self.entries.range(lo..=hi) {
            let (_, p, s, b) = k;
            if let Some((_, bp, bs, _)) = best {
                if (p, s) != (bp, bs) {
                    break; // past the winning group
                }
            }
            if s >= size.1 && b <= budget {
                best = Some(k); // later same-group entries have larger budgets
            }
        }
        match best.and_then(|k| self.entries.get(&k).cloned().map(|p| (k, p))) {
            Some((key, plan)) => {
                self.stats.hits += 1;
                obs::inc("shared_cache.hits");
                self.lru.touch(key);
                Some(plan)
            }
            None => {
                self.stats.misses += 1;
                obs::inc("shared_cache.misses");
                None
            }
        }
    }

    pub fn insert(&mut self, signature: u64, size: SizeKey, budget: u64, plan: Plan) {
        let key = (signature, size.0, size.1, budget);
        let novel = !self.entries.contains_key(&key);
        if novel && self.capacity > 0 && self.entries.len() >= self.capacity {
            if let Some(victim) = self.lru.pop_lru() {
                self.entries.remove(&victim);
                self.stats.evictions += 1;
                obs::inc("shared_cache.evictions");
            }
        }
        self.entries.insert(key, plan);
        self.lru.touch(key);
    }

    /// Drop one entry — a tenant invalidating a plan it contributed (e.g.
    /// its estimator is about to be retrained after a reshelter).
    pub fn remove(&mut self, signature: u64, size: SizeKey, budget: u64) {
        let key = (signature, size.0, size.1, budget);
        if self.entries.remove(&key).is_some() {
            self.lru.remove(&key);
            obs::inc("shared_cache.purges");
        }
    }

    pub fn clear(&mut self) {
        self.entries.clear();
        self.lru.clear();
    }

    /// Serialize every entry to the versioned on-disk format (see module
    /// persistence docs). Model signatures are encoded as decimal STRINGS:
    /// they are full 64-bit FNV hashes, and [`Json::Num`] is an f64 that
    /// silently corrupts integers above 2^53.
    pub fn save_string(&self) -> String {
        let mut out = String::with_capacity(64 + 64 * self.entries.len());
        out.push_str("{\"format\":\"");
        out.push_str(CACHE_FORMAT);
        out.push_str("\",\"version\":");
        out.push_str(&CACHE_VERSION.to_string());
        out.push_str(",\"kind\":\"shared\",\"entries\":[");
        for (i, ((sig, p, s, budget), plan)) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"sig\":\"{sig}\",\"primary\":{p},\"secondary\":{s},\"budget\":{budget},\"plan\":{}}}",
                ids_json(plan)
            ));
        }
        out.push_str("]}");
        out
    }

    /// Parse a cache saved by [`Self::save_string`] into a fresh cache with
    /// the given capacity bound. Errors (the caller's cue to fall back to a
    /// cold cache) on malformed JSON, an unknown format marker, or a
    /// version other than [`CACHE_VERSION`] — a stale layout never
    /// half-loads. Signature scoping needs no filtering here: every lookup
    /// key embeds the probing tenant's signature, so entries from models
    /// not in the new fleet are simply never hit.
    pub fn load_string(s: &str, capacity: usize) -> Result<SharedPlanCache, String> {
        let doc = Json::parse(s).map_err(|e| e.to_string())?;
        check_header(&doc, "shared")?;
        let mut cache = SharedPlanCache::new(capacity);
        for e in doc.get("entries").and_then(Json::as_arr).ok_or("missing entries array")? {
            let sig = parse_u64_str(e, "sig")?;
            let p = parse_u64_num(e, "primary")?;
            let sec = parse_u64_num(e, "secondary")?;
            let budget = parse_u64_num(e, "budget")?;
            cache.insert(sig, (p, sec), budget, parse_plan(e)?);
        }
        cache.stats = CacheStats::default(); // loads are not hits
        Ok(cache)
    }

    /// Write the cache to `path` ([`Self::save_string`] format).
    pub fn save_to_path(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.save_string())
    }

    /// Load a cache from `path`, or a cold one (plus the reason) when the
    /// file is missing, corrupt, or a stale version — a warm start must
    /// never be able to fail a run.
    pub fn load_from_path(path: &str, capacity: usize) -> (SharedPlanCache, Option<String>) {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => return (SharedPlanCache::new(capacity), Some(format!("read {path}: {e}"))),
        };
        match SharedPlanCache::load_string(&text, capacity) {
            Ok(c) => (c, None),
            Err(e) => (SharedPlanCache::new(capacity), Some(format!("load {path}: {e}"))),
        }
    }
}

// ---------------------------------------------------------------------------
// Persistence (versioned JSON via util/json — no external serializer)
// ---------------------------------------------------------------------------
//
// Layout (one object, entries sorted by key — BTreeMap order — so saves are
// deterministic and diffable):
//
//   {"format":"mimose-plan-cache","version":1,"kind":"shared",
//    "entries":[{"sig":"<u64 as decimal string>","primary":N,"secondary":N,
//                "budget":N,"plan":[ids...]}, ...]}
//
// `kind` is "shared" or "local"; local entries carry no sig/budget. A
// reader rejects (→ cold start) any format/version/kind mismatch outright
// rather than guessing at field semantics that may have changed.

/// Format marker in the persistence header.
pub const CACHE_FORMAT: &str = "mimose-plan-cache";
/// Bump on any layout change; old files then fall back to cold.
pub const CACHE_VERSION: u64 = 1;

fn ids_json(plan: &Plan) -> String {
    let ids: Vec<String> = plan.ids().iter().map(|i| i.to_string()).collect();
    format!("[{}]", ids.join(","))
}

fn check_header(doc: &Json, kind: &str) -> Result<(), String> {
    match doc.get("format").and_then(Json::as_str) {
        Some(f) if f == CACHE_FORMAT => {}
        other => return Err(format!("not a plan-cache file (format {other:?})")),
    }
    match doc.get("version").and_then(Json::as_f64) {
        Some(v) if v == CACHE_VERSION as f64 => {}
        other => return Err(format!("stale cache version {other:?}, want {CACHE_VERSION}")),
    }
    match doc.get("kind").and_then(Json::as_str) {
        Some(k) if k == kind => Ok(()),
        other => Err(format!("cache kind {other:?}, want {kind:?}")),
    }
}

fn parse_u64_str(e: &Json, key: &str) -> Result<u64, String> {
    e.get(key)
        .and_then(Json::as_str)
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or_else(|| format!("bad {key}"))
}

fn parse_u64_num(e: &Json, key: &str) -> Result<u64, String> {
    let n = e.get(key).and_then(Json::as_f64).ok_or_else(|| format!("bad {key}"))?;
    if n < 0.0 || n.fract() != 0.0 || n > (1u64 << 53) as f64 {
        return Err(format!("bad {key}: {n}"));
    }
    Ok(n as u64)
}

fn parse_plan(e: &Json) -> Result<Plan, String> {
    let arr = e.get("plan").and_then(Json::as_arr).ok_or("bad plan")?;
    let mut ids = Vec::with_capacity(arr.len());
    for v in arr {
        ids.push(v.as_usize().ok_or("bad plan id")?);
    }
    Ok(Plan::of(ids))
}

impl PlanCache {
    /// Serialize the per-job cache ([`SharedPlanCache::save_string`]'s
    /// format with `kind` "local" and `(primary, secondary)` keys).
    pub fn save_string(&self) -> String {
        let mut out = String::with_capacity(64 + 48 * self.plans.len());
        out.push_str("{\"format\":\"");
        out.push_str(CACHE_FORMAT);
        out.push_str("\",\"version\":");
        out.push_str(&CACHE_VERSION.to_string());
        out.push_str(",\"kind\":\"local\",\"entries\":[");
        for (i, ((p, s), plan)) in self.plans.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"primary\":{p},\"secondary\":{s},\"plan\":{}}}",
                ids_json(plan)
            ));
        }
        out.push_str("]}");
        out
    }

    /// Parse a [`Self::save_string`] dump into a fresh cache with the given
    /// tolerance/capacity; errors on corrupt or version-mismatched input.
    pub fn load_string(s: &str, tolerance: f64, capacity: usize) -> Result<PlanCache, String> {
        let doc = Json::parse(s).map_err(|e| e.to_string())?;
        check_header(&doc, "local")?;
        let mut cache = PlanCache::with_capacity(tolerance, capacity);
        for e in doc.get("entries").and_then(Json::as_arr).ok_or("missing entries array")? {
            let p = parse_u64_num(e, "primary")?;
            let sec = parse_u64_num(e, "secondary")?;
            cache.insert((p, sec), parse_plan(e)?);
        }
        cache.stats = CacheStats::default();
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{ensure, forall};

    #[test]
    fn exact_hit() {
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 0), Plan::of([1, 2]));
        assert_eq!(c.lookup1(1000), Some(Plan::of([1, 2])));
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn tolerant_hit_within_5_percent() {
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 0), Plan::of([3]));
        assert!(c.lookup1(1040).is_some());
        assert!(c.lookup1(960).is_some());
        assert!(c.lookup1(1100).is_none());
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn tolerance_boundary_above_key() {
        // Window is relative to the PROBE: [probe - floor(0.05*probe),
        // probe + floor(0.05*probe)]. For key 1000: probe 1052 still spans
        // down to 1000 (tol 52); probe 1053 bottoms out at 1001 — miss.
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 0), Plan::of([1]));
        assert!(c.lookup1(1052).is_some(), "probe 1052 reaches key 1000");
        assert!(c.lookup1(1053).is_none(), "probe 1053 is just outside");
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn tolerance_boundary_below_key() {
        // From below, probe 953 (tol 47) tops out exactly at 1000 — hit;
        // probe 952 tops out at 999 — miss.
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 0), Plan::of([1]));
        assert!(c.lookup1(953).is_some(), "probe 953 reaches key 1000");
        assert!(c.lookup1(952).is_none(), "probe 952 is just outside");
    }

    // ---- 2-D InputKey quantisation boundaries ----

    #[test]
    fn secondary_axis_has_its_own_tolerance_window() {
        // A near-match on the primary axis must NOT excuse a secondary axis
        // outside its own ±5% window (seq2seq: same src, very different tgt).
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 800), Plan::of([7]));
        assert!(c.lookup((1000, 800)).is_some(), "exact 2-D hit");
        assert!(c.lookup((1000, 840)).is_some(), "tgt within 5%");
        assert!(c.lookup((1010, 790)).is_some(), "both axes within 5%");
        assert!(c.lookup((1000, 900)).is_none(), "tgt 12.5% off: miss");
        assert!(c.lookup((1200, 800)).is_none(), "src 20% off: miss");
    }

    #[test]
    fn secondary_tolerance_boundary_exact() {
        // Same boundary arithmetic as the primary axis, independently:
        // probe tgt 840 has tol floor(0.05*840)=42, reaching down to 798;
        // probe 842 has tol 42, bottoming at 800 — hit; 843 floors at 801.
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 800), Plan::of([1]));
        assert!(c.lookup((1000, 842)).is_some(), "tgt 842 reaches key 800");
        assert!(c.lookup((1000, 843)).is_none(), "tgt 843 is just outside");
        // from below: probe 762 tops out at 800 (tol 38); 761 tops at 799
        assert!(c.lookup((1000, 762)).is_some());
        assert!(c.lookup((1000, 761)).is_none());
    }

    #[test]
    fn one_d_and_two_d_entries_never_mix() {
        // secondary 0 marks a single-axis plan; a 2-D probe must not reuse
        // it (and vice versa) — the decoder axis was never planned for.
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 0), Plan::of([1]));
        c.insert((1000, 500), Plan::of([2]));
        assert_eq!(c.lookup((1000, 0)), Some(Plan::of([1])));
        assert_eq!(c.lookup((1000, 500)), Some(Plan::of([2])));
        assert!(c.lookup((1000, 20)).is_none(), "small tgt never matches the 1-D entry");
    }

    #[test]
    fn nearest_two_d_key_wins() {
        let mut c = PlanCache::new(0.10);
        c.insert((1000, 500), Plan::of([1]));
        c.insert((1000, 530), Plan::of([2]));
        assert_eq!(c.lookup((1000, 525)), Some(Plan::of([2])));
        c.insert((1080, 500), Plan::of([3]));
        // primary distance dominates the nearest choice
        assert_eq!(c.lookup((1070, 505)), Some(Plan::of([3])));
    }

    #[test]
    fn lookup_exact_requires_exact_key() {
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 0), Plan::of([4]));
        assert_eq!(c.lookup_exact((1000, 0)), Some(Plan::of([4])));
        assert!(c.lookup_exact((1001, 0)).is_none(), "no tolerance on the exact path");
        assert!(c.lookup_exact((1000, 1)).is_none(), "secondary axis is part of the key");
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn stats_accounting_and_hit_rate() {
        let mut c = PlanCache::new(0.05);
        assert_eq!(c.stats().hit_rate(), 0.0, "empty stats are a 0 rate, not NaN");
        c.insert((1000, 0), Plan::none());
        let _ = c.lookup1(1000); // hit
        let _ = c.lookup1(1010); // hit (within 5%)
        let _ = c.lookup1(2000); // miss
        let _ = c.lookup_exact((1000, 0)); // hit
        let _ = c.lookup_exact((1200, 0)); // miss
        assert_eq!(c.stats().hits, 3);
        assert_eq!(c.stats().misses, 2);
        assert!((c.stats().hit_rate() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn insert_same_key_overwrites() {
        let mut c = PlanCache::new(0.05);
        c.insert((500, 0), Plan::of([1]));
        c.insert((500, 0), Plan::of([2]));
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup_exact((500, 0)), Some(Plan::of([2])));
    }

    #[test]
    fn zero_tolerance_only_hits_exact() {
        let mut c = PlanCache::new(0.0);
        c.insert((1000, 0), Plan::of([9]));
        assert!(c.lookup1(1000).is_some());
        assert!(c.lookup1(1001).is_none());
        assert!(c.lookup1(999).is_none());
    }

    #[test]
    fn nearest_key_wins() {
        let mut c = PlanCache::new(0.10);
        c.insert((1000, 0), Plan::of([1]));
        c.insert((1080, 0), Plan::of([2]));
        assert_eq!(c.lookup1(1070), Some(Plan::of([2])));
    }

    #[test]
    fn clear_resets_entries_not_stats() {
        let mut c = PlanCache::new(0.05);
        c.insert((10, 0), Plan::none());
        let _ = c.lookup1(10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn capacity_evicts_least_recently_hit() {
        let mut c = PlanCache::with_capacity(0.0, 2);
        c.insert((100, 0), Plan::of([1]));
        c.insert((200, 0), Plan::of([2]));
        let _ = c.lookup_exact((100, 0)); // 100 is now fresher than 200
        c.insert((300, 0), Plan::of([3]));
        assert_eq!(c.len(), 2);
        assert!(c.lookup_exact((200, 0)).is_none(), "LRU entry 200 evicted");
        assert!(c.lookup_exact((100, 0)).is_some());
        assert!(c.lookup_exact((300, 0)).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn overwrite_at_capacity_does_not_evict() {
        let mut c = PlanCache::with_capacity(0.0, 2);
        c.insert((100, 0), Plan::of([1]));
        c.insert((200, 0), Plan::of([2]));
        c.insert((100, 0), Plan::of([9])); // same key: update, no eviction
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.lookup_exact((100, 0)), Some(Plan::of([9])));
    }

    #[test]
    fn capacity_respected_under_adversarial_stream() {
        // every insert a novel quantisation cell — the unbounded cache would
        // hold 1000 entries; the bound must hold at 8 with 992 evictions.
        let mut c = PlanCache::with_capacity(0.05, 8);
        for i in 0..1000u64 {
            c.insert((10_000 + i * 7919, 0), Plan::of([i as usize]));
            assert!(c.len() <= 8, "capacity exceeded at insert {i}");
        }
        assert_eq!(c.len(), 8);
        assert_eq!(c.stats().evictions, 992);
        // the 8 most recent survive
        for i in 992..1000u64 {
            assert!(c.lookup_exact((10_000 + i * 7919, 0)).is_some(), "entry {i} missing");
        }
    }

    #[test]
    fn zero_capacity_means_unbounded() {
        let mut c = PlanCache::new(0.05);
        for i in 0..500u64 {
            c.insert((1_000_000 + i * 997, 0), Plan::none());
        }
        assert_eq!(c.len(), 500);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn prop_hit_implies_key_within_tolerance() {
        forall(
            23,
            200,
            |r| {
                let keys: Vec<usize> = (0..r.range_u(1, 10)).map(|_| r.range_u(100, 10_000)).collect();
                let probe = r.range_u(100, 10_000);
                (keys, probe)
            },
            |(keys, probe)| {
                let mut c = PlanCache::new(0.05);
                for &k in keys {
                    c.insert((k as u64, 0), Plan::of([k]));
                }
                if let Some(plan) = c.lookup1(*probe as u64) {
                    let id = *plan.ids().first().unwrap();
                    let rel = (id as f64 - *probe as f64).abs() / *probe as f64;
                    ensure(rel <= 0.051, &format!("hit key {id} for probe {probe}: rel {rel}"))
                } else {
                    // miss: no key may lie within tolerance
                    for &k in keys {
                        let rel = (k as f64 - *probe as f64).abs() / *probe as f64;
                        ensure(rel > 0.05, &format!("missed key {k} within tol of {probe}"))?;
                    }
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn prop_two_d_hit_implies_both_axes_within_tolerance() {
        forall(
            29,
            200,
            |r| {
                let keys: Vec<(usize, usize)> = (0..r.range_u(1, 10))
                    .map(|_| (r.range_u(100, 10_000), r.range_u(100, 10_000)))
                    .collect();
                (keys, r.range_u(100, 10_000), r.range_u(100, 10_000))
            },
            |(keys, pp, ps)| {
                let mut c = PlanCache::new(0.05);
                for (i, &(kp, ks)) in keys.iter().enumerate() {
                    c.insert((kp as u64, ks as u64), Plan::of([i]));
                }
                if let Some(plan) = c.lookup((*pp as u64, *ps as u64)) {
                    let (kp, ks) = keys[*plan.ids().first().unwrap()];
                    let rp = (kp as f64 - *pp as f64).abs() / *pp as f64;
                    let rs = (ks as f64 - *ps as f64).abs() / *ps as f64;
                    ensure(
                        rp <= 0.051 && rs <= 0.051,
                        &format!("hit ({kp},{ks}) for probe ({pp},{ps}): rel ({rp},{rs})"),
                    )
                } else {
                    for &(kp, ks) in keys {
                        let rp = (kp as f64 - *pp as f64).abs() / *pp as f64;
                        let rs = (ks as f64 - *ps as f64).abs() / *ps as f64;
                        ensure(
                            rp > 0.05 || rs > 0.05,
                            &format!("missed ({kp},{ks}) within tol of ({pp},{ps})"),
                        )?;
                    }
                    Ok(())
                }
            },
        );
    }

    // ---- shared cross-job cache ----

    #[test]
    fn signature_distinguishes_architectures_batch_and_act_factor() {
        let bert = ModelSpec::bert_base();
        let roberta = ModelSpec::roberta_base();
        assert_eq!(model_signature(&bert, 32, 1.0), model_signature(&bert, 32, 1.0));
        assert_ne!(model_signature(&bert, 32, 1.0), model_signature(&roberta, 32, 1.0));
        assert_ne!(model_signature(&bert, 32, 1.0), model_signature(&bert, 12, 1.0));
        // same spec+batch but wider residuals (two-stream attention) must
        // NOT exchange plans — the 1.0 tenant's plan under-checkpoints
        assert_ne!(model_signature(&bert, 32, 1.0), model_signature(&bert, 32, 1.15));
        // an encoder-decoder with the same encoder trunk is a different model
        let mut s2s = bert.clone();
        s2s.decoder_layers = 6;
        assert_ne!(model_signature(&bert, 32, 1.0), model_signature(&s2s, 32, 1.0));
    }

    #[test]
    fn shared_reuse_requires_same_signature() {
        let mut c = SharedPlanCache::new(0);
        c.insert(1, (9600, 0), 6_000, Plan::of([1, 2]));
        assert_eq!(c.lookup(1, (9600, 0), 6_000), Some(Plan::of([1, 2])));
        assert!(c.lookup(2, (9600, 0), 6_000).is_none(), "other signature isolated");
        assert!(c.lookup(1, (9601, 0), 6_000).is_none(), "other size isolated");
        assert!(c.lookup(1, (9600, 64), 6_000).is_none(), "other secondary axis isolated");
    }

    #[test]
    fn shared_reuse_is_budget_conservative() {
        // a plan from a tighter budget is safe for a looser one, never the
        // other way around
        let mut c = SharedPlanCache::new(0);
        c.insert(7, (9600, 0), 5_000, Plan::of([1, 2, 3]));
        assert!(c.lookup(7, (9600, 0), 6_000).is_some(), "tighter-budget plan reused");
        assert!(c.lookup(7, (9600, 0), 5_000).is_some(), "equal budget reused");
        assert!(c.lookup(7, (9600, 0), 4_999).is_none(), "looser-budget plan refused");
    }

    #[test]
    fn shared_nearest_qualifying_budget_wins() {
        let mut c = SharedPlanCache::new(0);
        c.insert(7, (9600, 0), 4_000, Plan::of([1, 2, 3, 4]));
        c.insert(7, (9600, 0), 5_000, Plan::of([1, 2]));
        assert_eq!(c.lookup(7, (9600, 0), 6_000), Some(Plan::of([1, 2])), "least conservative");
        assert_eq!(c.lookup(7, (9600, 0), 4_500), Some(Plan::of([1, 2, 3, 4])));
    }

    #[test]
    fn shared_capacity_evicts_lru() {
        let mut c = SharedPlanCache::new(2);
        c.insert(1, (100, 0), 10, Plan::of([1]));
        c.insert(1, (200, 0), 10, Plan::of([2]));
        let _ = c.lookup(1, (100, 0), 10); // freshen
        c.insert(1, (300, 0), 10, Plan::of([3]));
        assert_eq!(c.len(), 2);
        assert!(c.lookup(1, (200, 0), 10).is_none());
        assert!(c.lookup(1, (100, 0), 10).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn shared_handle_is_shareable() {
        let h = shared_plan_cache(4);
        let h2 = h.clone();
        h.borrow_mut().insert(1, (50, 0), 10, Plan::of([5]));
        assert_eq!(h2.borrow_mut().lookup(1, (50, 0), 10), Some(Plan::of([5])));
    }

    #[test]
    fn shared_remove_targets_one_entry() {
        let mut c = SharedPlanCache::new(0);
        c.insert(1, (100, 50), 10, Plan::of([1]));
        c.insert(1, (100, 60), 10, Plan::of([2]));
        c.remove(1, (100, 50), 10);
        assert!(c.lookup(1, (100, 50), 10).is_none());
        assert!(c.lookup(1, (100, 60), 10).is_some());
    }

    #[test]
    fn holds_signature_is_a_pure_probe() {
        let mut c = SharedPlanCache::new(0);
        assert!(!c.holds_signature(7));
        c.insert(7, (9600, 0), 5_000, Plan::of([1, 2]));
        c.insert(u64::MAX, (100, 0), 10, Plan::of([3]));
        assert!(c.holds_signature(7), "any entry at the signature counts");
        assert!(c.holds_signature(u64::MAX), "boundary signature probes cleanly");
        assert!(!c.holds_signature(8), "adjacent signature stays cold");
        let before = c.stats().clone();
        let _ = c.holds_signature(7);
        assert_eq!(*c.stats(), before, "probe moves no stats");
        // and it does not freshen LRU order: insert two at capacity 2, probe
        // the older one, then overflow — the probed (but untouched) entry
        // must still be the eviction victim
        let mut small = SharedPlanCache::new(2);
        small.insert(1, (100, 0), 10, Plan::of([1]));
        small.insert(2, (200, 0), 10, Plan::of([2]));
        assert!(small.holds_signature(1));
        small.insert(3, (300, 0), 10, Plan::of([3]));
        assert!(!small.holds_signature(1), "probe did not freshen LRU");
        assert!(small.holds_signature(2) && small.holds_signature(3));
    }

    #[test]
    fn absorb_merges_per_device_caches() {
        let mut a = SharedPlanCache::new(0);
        a.insert(1, (100, 0), 10, Plan::of([1]));
        a.insert(2, (200, 0), 20, Plan::of([2]));
        let mut b = SharedPlanCache::new(0);
        b.insert(2, (200, 0), 20, Plan::of([9])); // same cell, newer plan
        b.insert(3, (300, 0), 30, Plan::of([3]));
        a.absorb(&b);
        assert_eq!(a.len(), 3, "union of cells");
        assert_eq!(a.lookup(1, (100, 0), 10), Some(Plan::of([1])), "own entry kept");
        assert_eq!(a.lookup(2, (200, 0), 20), Some(Plan::of([9])), "absorbed overwrites");
        assert_eq!(a.lookup(3, (300, 0), 30), Some(Plan::of([3])), "new cell adopted");
        assert_eq!(b.len(), 2, "donor untouched");
        // capacity rules still apply on the receiving side
        let mut tight = SharedPlanCache::new(2);
        tight.absorb(&a);
        assert_eq!(tight.len(), 2, "absorb respects the receiver's capacity");
    }

    // ---- persistence ----

    #[test]
    fn shared_round_trip_preserves_every_lookup() {
        let mut c = SharedPlanCache::new(0);
        // a signature above 2^53 — the exact value f64 JSON numbers mangle
        let big_sig = 0xdead_beef_cafe_f00du64;
        c.insert(big_sig, (9600, 0), 5_000, Plan::of([1, 2, 3]));
        c.insert(big_sig, (9600, 128), 5_000, Plan::of([2]));
        c.insert(7, (480, 0), 2_000, Plan::none());
        let text = c.save_string();
        let mut back = SharedPlanCache::load_string(&text, 0).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.lookup(big_sig, (9600, 0), 6_000), Some(Plan::of([1, 2, 3])));
        assert_eq!(back.lookup(big_sig, (9600, 128), 5_000), Some(Plan::of([2])));
        assert_eq!(back.lookup(7, (480, 0), 2_000), Some(Plan::none()));
        assert!(back.lookup(big_sig, (9600, 0), 4_999).is_none(), "budget scoping survives");
        assert!(back.lookup(8, (480, 0), 2_000).is_none(), "wrong signature never hits");
        // and a second generation is byte-identical (deterministic saves)
        let mut c2 = SharedPlanCache::load_string(&text, 0).unwrap();
        assert_eq!(c2.save_string(), text);
        assert!(c2.lookup(7, (480, 0), 2_000).is_some());
    }

    #[test]
    fn corrupt_and_stale_files_are_rejected_not_half_loaded() {
        assert!(SharedPlanCache::load_string("{not json", 0).is_err());
        assert!(SharedPlanCache::load_string("{\"format\":\"other\"}", 0).is_err());
        let stale = "{\"format\":\"mimose-plan-cache\",\"version\":999,\
                     \"kind\":\"shared\",\"entries\":[]}";
        assert!(SharedPlanCache::load_string(stale, 0).is_err(), "future version is stale");
        let wrong_kind = SharedPlanCache::new(0).save_string().replace("shared", "local");
        assert!(SharedPlanCache::load_string(&wrong_kind, 0).is_err());
        // a local dump is not a shared dump
        let local = PlanCache::new(0.05).save_string();
        assert!(SharedPlanCache::load_string(&local, 0).is_err());
        // path helper: missing file falls back cold with a reason
        let (cold, why) = SharedPlanCache::load_from_path("/nonexistent/cache.json", 4);
        assert!(cold.is_empty());
        assert!(why.is_some());
    }

    #[test]
    fn local_round_trip_preserves_tolerant_lookup() {
        let mut c = PlanCache::new(0.05);
        c.insert((1000, 800), Plan::of([7]));
        c.insert((500, 0), Plan::of([1, 4]));
        let text = c.save_string();
        let mut back = PlanCache::load_string(&text, 0.05, 0).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.lookup((1010, 790)), Some(Plan::of([7])), "tolerance works post-load");
        assert_eq!(back.lookup_exact((500, 0)), Some(Plan::of([1, 4])));
        assert_eq!(back.stats().hits, 2);
    }
}
