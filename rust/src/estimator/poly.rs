//! Polynomial regression (paper §4.3/§6.5): the "lightning memory estimator".
//! Order n=2 (quadratic) is the paper's pick — activation bytes are at most
//! quadratic in the input size (attention probs), so 10 samples suffice for
//! thousandth-level error (Tables 3 & 4).

use super::linalg::lstsq;
use super::Regressor;

#[derive(Clone, Debug)]
pub struct PolyRegressor {
    pub order: usize,
    /// Coefficients low->high; empty until trained.
    pub coef: Vec<f64>,
    /// Feature scaling for conditioning (inputs are ~1e2..1e5 elements).
    scale: f64,
}

impl PolyRegressor {
    pub fn new(order: usize) -> Self {
        assert!(order >= 1 && order <= 8);
        PolyRegressor { order, coef: Vec::new(), scale: 1.0 }
    }
}

impl Regressor for PolyRegressor {
    fn name(&self) -> String {
        format!("Polynomial (n={})", self.order)
    }

    fn fit(&mut self, xs: &[f64], ys: &[f64]) {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        self.scale = xs.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1.0);
        let k = self.order + 1;
        let mut design = Vec::with_capacity(xs.len() * k);
        for &x in xs {
            let mut p = 1.0;
            let xn = x / self.scale;
            for _ in 0..k {
                design.push(p);
                p *= xn;
            }
        }
        self.coef = lstsq(&design, ys, xs.len(), k, 1e-9)
            .unwrap_or_else(|| vec![ys.iter().sum::<f64>() / ys.len() as f64]);
    }

    fn predict(&self, x: f64) -> f64 {
        let xn = x / self.scale;
        let mut acc = 0.0;
        let mut p = 1.0;
        for &c in &self.coef {
            acc += c * p;
            p *= xn;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn quadratic_recovers_quadratic_exactly() {
        let mut r = PolyRegressor::new(2);
        let xs: Vec<f64> = (1..=10).map(|i| (i * 50) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 1e6 + 2e3 * x + 3.5 * x * x).collect();
        r.fit(&xs, &ys);
        for &x in &[75.0, 333.0, 512.0] {
            let want = 1e6 + 2e3 * x + 3.5 * x * x;
            let rel = (r.predict(x) - want).abs() / want;
            assert!(rel < 1e-6, "rel={rel}");
        }
    }

    #[test]
    fn linear_underfits_quadratic() {
        let xs: Vec<f64> = (1..=10).map(|i| (i * 50) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 1e6 + 2e3 * x + 3.5 * x * x).collect();
        let mut lin = PolyRegressor::new(1);
        let mut quad = PolyRegressor::new(2);
        lin.fit(&xs, &ys);
        quad.fit(&xs, &ys);
        let x = 275.0;
        let want = 1e6 + 2e3 * x + 3.5 * x * x;
        assert!((lin.predict(x) - want).abs() > (quad.predict(x) - want).abs());
    }

    #[test]
    fn single_sample_degenerates_to_constant() {
        let mut r = PolyRegressor::new(2);
        r.fit(&[100.0], &[5.0]);
        assert!((r.predict(100.0) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn prop_fit_interpolates_training_points() {
        // For >= order+1 distinct samples of an exact polynomial, training
        // points are reproduced to high precision.
        forall(
            3,
            30,
            |rng| {
                let n = rng.range_u(4, 12);
                (0..n).map(|i| (i + 1) as f64 * rng.range_f(10.0, 50.0)).collect::<Vec<f64>>()
            },
            |xs| {
                let ys: Vec<f64> = xs.iter().map(|&x| 7.0 + 0.3 * x + 0.02 * x * x).collect();
                let mut r = PolyRegressor::new(2);
                r.fit(xs, &ys);
                for (&x, &y) in xs.iter().zip(&ys) {
                    let rel = (r.predict(x) - y).abs() / y.abs().max(1e-9);
                    if rel > 1e-5 {
                        return Err(format!("rel {rel} at x={x}"));
                    }
                }
                Ok(())
            },
        );
    }
}
