//! The L3 Coordinator: the paper's online-training control loop as an
//! explicit state machine.
//!
//! Mimose's contribution is not any single component but the *composition*
//! running inside a live training job (§4.1): sheltered collection feeds the
//! estimator, a freeze point trains it, and responsive execution serves
//! plans from a cache keyed by input size. This module owns that composition
//! so engines and planners stop hand-wiring the stages.
//!
//! # Phases
//!
//! * [`Phase::Sheltered`] — shuttling double-forward measurement (§4.2,
//!   Fig 7). The iteration runs under the conservative everything-
//!   checkpointed plan while the [`Collector`] records per-layer
//!   `(input size, activation bytes, forward ms)` observations, filtered
//!   per Fig 12 before reaching the [`MemoryEstimator`].
//! * [`Phase::Frozen`] — the estimator is (re)trained and Algorithm 1
//!   (§4.4) generates a plan for an input size the [`PlanCache`] has not
//!   seen; the plan is inserted under the quantised size key. An iteration
//!   is tagged `Frozen` exactly when it paid a replan.
//! * [`Phase::Executing`] — responsive execution (§5): the quantised input
//!   size hits the plan cache and the cached plan is applied with ~µs
//!   lookup cost.
//!
//! A novel input size appearing after the warmup window can re-trigger
//! sheltered collection (§4.2's O(n/N) amortisation note) when
//! [`CoordinatorConfig::reshelter_on_novel`] is set; the collector is
//! re-opened for one iteration and the estimator retrained with the new
//! sample at the next freeze point.
//!
//! Phase changes are recorded as [`Transition`]s, and [`Coordinator::stats`]
//! snapshots the run counters (cache hit rate, replan latency, reshelter
//! count) that `metrics::RunReport` and the `mimose sim` CLI report.

use crate::collector::{Collector, Observation};
use crate::config::{CoordinatorConfig, MimoseConfig};
use crate::estimator::MemoryEstimator;
use crate::model::ModelProfile;
use crate::planners::{
    checkpointable, usable_activation_budget, InputDesc, IterationMode, PlanDecision,
};
use crate::scheduler::{greedy_schedule, LayerEst, Plan, PlanCache};
use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// Which stage of the paper's online pipeline an iteration ran in.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Phase {
    /// Shuttling collection under the conservative plan (§4.2).
    Sheltered,
    /// Estimator train + Algorithm 1 replan on a cache miss (§4.3, §4.4).
    Frozen,
    /// Cached-plan application — responsive execution (§5).
    #[default]
    Executing,
    /// No up-front plan; reactive eviction on OOM (DTR baseline only —
    /// never produced by the Coordinator, but engines tag DTR iterations
    /// with it so reports can partition every iteration by phase).
    Reactive,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Sheltered => "sheltered",
            Phase::Frozen => "frozen",
            Phase::Executing => "executing",
            Phase::Reactive => "reactive",
        }
    }
}

impl std::fmt::Display for Phase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One recorded phase change.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transition {
    /// 1-based iteration index at which the new phase took effect.
    pub iter: u64,
    pub from: Phase,
    pub to: Phase,
    /// Input size (batch * seqlen) of the triggering iteration.
    pub input_size: u64,
}

/// Counter snapshot for reporting (the Table 2 / §6.3 numbers).
#[derive(Clone, Debug)]
pub struct CoordinatorStats {
    pub phase: Phase,
    pub iterations: u64,
    pub plans_generated: u64,
    pub reshelters: u64,
    pub cache_entries: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_hit_rate: f64,
    pub train_ms: f64,
    pub plan_ms_total: f64,
    /// Mean / max wall time of cache-miss replans (estimator + Algorithm 1).
    pub replan_ms_mean: f64,
    pub replan_ms_max: f64,
    /// Total phase changes over the run (the recorded log may be shorter
    /// when `max_transitions` capped it).
    pub transitions: u64,
}

/// Round `size` up to the next point of a geometric grid with step
/// `(1 + tol)` — all sizes in one grid cell share one (conservative) plan.
pub fn quantize_up(size: u64, tol: f64) -> u64 {
    if size == 0 {
        return 0;
    }
    let step = (1.0 + tol.max(1e-6)).ln();
    let cell = ((size as f64).ln() / step).ceil();
    (cell * step).exp().ceil() as u64
}

/// Synthesise per-layer collector observations from an analytic profile —
/// what a sheltered forward would measure on an engine whose ground truth
/// *is* the profile. `fwd_ms_of` maps layer forward FLOPs to wall ms
/// (engines pass their cost model; offline planning passes a FLOPs proxy).
pub fn observations_from_profile<F: Fn(u64) -> f64>(
    profile: &ModelProfile,
    input: &InputDesc,
    fwd_ms_of: F,
) -> Vec<Observation> {
    profile
        .layers
        .iter()
        .map(|l| Observation {
            layer: l.id,
            input_size: input.size() as f64,
            act_bytes: l.act_bytes,
            fwd_ms: fwd_ms_of(l.fwd_flops),
            // pass one of the shuttling double-forward measures *before*
            // dropping state, so nothing is polluted by checkpointing
            // (Fig 7; the Fig 12 filter matters for eager-mode nesting)
            self_checkpointed: false,
            relative_checkpointed: false,
        })
        .collect()
}

/// The online-training orchestrator: collector -> estimator -> scheduler ->
/// cache, behind one `begin_iteration` / `end_iteration` seam.
pub struct Coordinator {
    cfg: MimoseConfig,
    ccfg: CoordinatorConfig,
    budget: u64,
    collector: Collector,
    estimator: MemoryEstimator,
    cache: PlanCache,
    phase: Phase,
    iter: u64,
    transitions: Vec<Transition>,
    /// Every phase change, including those the capped log dropped.
    transitions_seen: u64,
    replan_ms: Summary,
    /// Estimator training time accumulated across (re)freezes.
    pub train_ms: f64,
    /// Total estimator+scheduler time across the run (Table 2 column).
    pub plan_ms_total: f64,
    /// Number of plans generated (cache misses that ran Algorithm 1).
    pub plans_generated: u64,
    /// Times a novel input size re-opened sheltered collection (§4.2).
    pub reshelters: u64,
    estimator_ready: bool,
}

impl Coordinator {
    pub fn new(budget: u64, n_layers: usize, cfg: MimoseConfig, ccfg: CoordinatorConfig) -> Self {
        Coordinator {
            collector: Collector::new(cfg.collect_iters),
            estimator: MemoryEstimator::new(n_layers),
            cache: PlanCache::new(cfg.cache_tolerance),
            cfg,
            ccfg,
            budget,
            phase: Phase::Sheltered,
            iter: 0,
            transitions: Vec::new(),
            transitions_seen: 0,
            replan_ms: Summary::new(),
            train_ms: 0.0,
            plan_ms_total: 0.0,
            plans_generated: 0,
            reshelters: 0,
            estimator_ready: false,
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn iterations(&self) -> u64 {
        self.iter
    }

    pub fn collector(&self) -> &Collector {
        &self.collector
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    pub fn estimator(&self) -> &MemoryEstimator {
        &self.estimator
    }

    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    pub fn stats(&self) -> CoordinatorStats {
        let cs = self.cache.stats();
        CoordinatorStats {
            phase: self.phase,
            iterations: self.iter,
            plans_generated: self.plans_generated,
            reshelters: self.reshelters,
            cache_entries: self.cache.len(),
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_hit_rate: cs.hit_rate(),
            train_ms: self.train_ms,
            plan_ms_total: self.plan_ms_total,
            replan_ms_mean: if self.replan_ms.count() == 0 { 0.0 } else { self.replan_ms.mean() },
            replan_ms_max: if self.replan_ms.count() == 0 { 0.0 } else { self.replan_ms.max() },
            transitions: self.transitions_seen,
        }
    }

    fn set_phase(&mut self, to: Phase, input_size: u64) {
        if self.phase != to {
            self.transitions_seen += 1;
            if self.ccfg.track_transitions && self.transitions.len() < self.ccfg.max_transitions {
                self.transitions.push(Transition { iter: self.iter, from: self.phase, to, input_size });
            }
            self.phase = to;
        }
    }

    /// Conservative plan for sheltered execution: checkpoint every
    /// checkpointable layer (the Sublinear-style envelope of §4.2 — memory
    /// footprint equals the static planner's while we measure).
    pub fn conservative_plan(profile: &ModelProfile) -> Plan {
        Plan::of(checkpointable(profile).into_iter().map(|l| l.id))
    }

    /// Algorithm 1 over *estimated* per-layer bytes.
    fn generate_plan(&mut self, input_size: u64, profile: &ModelProfile) -> Plan {
        let layers: Vec<LayerEst> = checkpointable(profile)
            .into_iter()
            .map(|mut l| {
                l.est_bytes = self.estimator.predict_bytes(l.id, input_size as f64) as u64;
                l
            })
            .collect();
        let est_total: u64 = layers.iter().map(|l| l.est_bytes).sum();
        let usable = usable_activation_budget(self.budget, profile, self.cfg.reserve_bytes);
        let excess = est_total.saturating_sub(usable);
        greedy_schedule(&layers, excess, self.cfg.bucket_tolerance)
    }

    /// Decide how to run one iteration — the state-machine step.
    pub fn begin_iteration(&mut self, input: &InputDesc, profile: &ModelProfile) -> PlanDecision {
        self.iter += 1;
        let size = input.size();
        // Quantise the planning size UP to the cache grid so that a cached
        // plan is always conservative for every input mapped to it (a plan
        // generated for a slightly smaller input could under-checkpoint).
        let plan_size = quantize_up(size, self.cfg.cache_tolerance);

        // ---- sheltered execution (§4.2) ----
        let mut shelter = self.collector.wants_collection(size);
        if !shelter
            && self.ccfg.reshelter_on_novel
            && self.collector.is_frozen()
            && !self.collector.seen(size)
        {
            // novel input size after the warmup window: re-open collection
            // for one iteration and retrain the estimator at the next freeze.
            // Cached plans were built from the stale estimator — drop them so
            // every size replans against the retrained fits (regeneration is
            // sub-millisecond; cache stats survive a clear).
            self.collector.reopen(1);
            self.estimator_ready = false;
            self.cache.clear();
            self.reshelters += 1;
            shelter = true;
        }
        if shelter {
            self.set_phase(Phase::Sheltered, size);
            return PlanDecision {
                mode: IterationMode::Sheltered(Self::conservative_plan(profile)),
                planning_ms: 0.0,
                cache_hit: false,
                phase: Phase::Sheltered,
            };
        }

        // ---- responsive execution (§4.3-§4.4, §5) ----
        let t = Timer::start();
        if !self.estimator_ready {
            self.train_ms += self.estimator.train();
            self.estimator_ready = true;
        }
        if let Some(plan) = self.cache.lookup_exact(plan_size) {
            let planning_ms = t.elapsed_ms();
            self.plan_ms_total += planning_ms;
            self.set_phase(Phase::Executing, size);
            return PlanDecision {
                mode: IterationMode::Planned(plan),
                planning_ms,
                cache_hit: true,
                phase: Phase::Executing,
            };
        }
        let plan = self.generate_plan(plan_size, profile);
        self.cache.insert(plan_size, plan.clone());
        self.plans_generated += 1;
        let planning_ms = t.elapsed_ms();
        self.plan_ms_total += planning_ms;
        self.replan_ms.add(planning_ms);
        self.set_phase(Phase::Frozen, size);
        PlanDecision {
            mode: IterationMode::Planned(plan),
            planning_ms,
            cache_hit: false,
            phase: Phase::Frozen,
        }
    }

    /// Feed back one iteration's sheltered observations (no-op once frozen).
    pub fn end_iteration(&mut self, input: &InputDesc, obs: &[Observation], extra_fwd_ms: f64) {
        if !self.collector.is_frozen() && !obs.is_empty() {
            self.collector.ingest(&mut self.estimator, input.size(), obs, extra_fwd_ms);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::model::transformer_profile;
    use crate::util::GIB;

    fn spec() -> ModelSpec {
        ModelSpec::bert_base()
    }

    fn coord(reshelter: bool) -> Coordinator {
        Coordinator::new(
            6 * GIB,
            14,
            MimoseConfig::default(),
            CoordinatorConfig { reshelter_on_novel: reshelter, ..Default::default() },
        )
    }

    /// Run one sheltered iteration at the given seqlen.
    fn shelter_once(c: &mut Coordinator, seq: usize) {
        let profile = transformer_profile(&spec(), 32, seq, 1.0);
        let input = InputDesc { batch: 32, seqlen: seq };
        let dec = c.begin_iteration(&input, &profile);
        assert!(matches!(dec.mode, IterationMode::Sheltered(_)), "seq {seq} not sheltered");
        let obs = observations_from_profile(&profile, &input, |f| f as f64 / 1e9);
        c.end_iteration(&input, &obs, 1.0);
    }

    fn warmup(c: &mut Coordinator) {
        // 10 distinct sizes spanning the TC-Bert range
        for seq in [60, 90, 120, 150, 180, 210, 240, 270, 300, 330] {
            shelter_once(c, seq);
        }
        assert!(c.collector().is_frozen());
    }

    #[test]
    fn phases_progress_sheltered_frozen_executing() {
        let mut c = coord(false);
        assert_eq!(c.phase(), Phase::Sheltered);
        warmup(&mut c);
        let profile = transformer_profile(&spec(), 32, 200, 1.0);
        let input = InputDesc { batch: 32, seqlen: 200 };
        let d = c.begin_iteration(&input, &profile);
        assert_eq!(d.phase, Phase::Frozen);
        assert!(!d.cache_hit);
        let d = c.begin_iteration(&input, &profile);
        assert_eq!(d.phase, Phase::Executing);
        assert!(d.cache_hit);
        // transitions recorded in order
        let names: Vec<&str> = c.transitions().iter().map(|t| t.to.name()).collect();
        assert_eq!(names, vec!["frozen", "executing"]);
        assert_eq!(c.stats().transitions, 2);
    }

    #[test]
    fn novel_size_reshelters_when_enabled() {
        let mut c = coord(true);
        warmup(&mut c);
        // known size: responsive
        let profile = transformer_profile(&spec(), 32, 300, 1.0);
        let d = c.begin_iteration(&InputDesc { batch: 32, seqlen: 300 }, &profile);
        assert!(matches!(d.mode, IterationMode::Planned(_)));
        // novel size (far from every collected size): re-shelters once
        let profile = transformer_profile(&spec(), 32, 512, 1.0);
        let input = InputDesc { batch: 32, seqlen: 512 };
        let d = c.begin_iteration(&input, &profile);
        assert_eq!(d.phase, Phase::Sheltered);
        let obs = observations_from_profile(&profile, &input, |f| f as f64 / 1e9);
        c.end_iteration(&input, &obs, 1.0);
        assert_eq!(c.reshelters, 1);
        assert!(c.collector().is_frozen(), "one-shot reshelter must refreeze");
        // same size again: now known, responsive
        let d = c.begin_iteration(&input, &profile);
        assert!(matches!(d.mode, IterationMode::Planned(_)));
    }

    #[test]
    fn novel_size_does_not_reshelter_when_disabled() {
        let mut c = coord(false);
        warmup(&mut c);
        let profile = transformer_profile(&spec(), 32, 512, 1.0);
        let d = c.begin_iteration(&InputDesc { batch: 32, seqlen: 512 }, &profile);
        assert!(matches!(d.mode, IterationMode::Planned(_)));
        assert_eq!(c.reshelters, 0);
    }

    #[test]
    fn stats_snapshot_tracks_cache_and_replans() {
        let mut c = coord(false);
        warmup(&mut c);
        let profile = transformer_profile(&spec(), 32, 250, 1.0);
        let input = InputDesc { batch: 32, seqlen: 250 };
        let _ = c.begin_iteration(&input, &profile); // miss -> replan
        let _ = c.begin_iteration(&input, &profile); // hit
        let s = c.stats();
        assert_eq!(s.plans_generated, 1);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 1);
        assert!((s.cache_hit_rate - 0.5).abs() < 1e-9);
        assert!(s.replan_ms_max >= s.replan_ms_mean);
        assert!(s.train_ms >= 0.0 && s.plan_ms_total >= 0.0);
        assert_eq!(s.iterations, 12);
    }

    #[test]
    fn quantize_up_is_monotone_and_conservative() {
        for &tol in &[0.02, 0.05, 0.1] {
            let mut prev = 0;
            for size in [1u64, 7, 100, 1000, 9600, 10_624, 1 << 20] {
                let q = quantize_up(size, tol);
                assert!(q >= size, "quantized below input");
                assert!(q >= prev, "not monotone");
                // never more than one grid step above the input
                assert!(q as f64 <= size as f64 * (1.0 + tol) + 1.0, "{size} -> {q} (tol {tol})");
                prev = q;
            }
        }
        assert_eq!(quantize_up(0, 0.05), 0);
    }

    #[test]
    fn transition_log_capped() {
        let mut c = Coordinator::new(
            6 * GIB,
            14,
            MimoseConfig::default(),
            CoordinatorConfig { max_transitions: 1, ..Default::default() },
        );
        warmup(&mut c);
        let profile = transformer_profile(&spec(), 32, 200, 1.0);
        let input = InputDesc { batch: 32, seqlen: 200 };
        let _ = c.begin_iteration(&input, &profile);
        let _ = c.begin_iteration(&input, &profile);
        assert_eq!(c.transitions().len(), 1, "log must respect the cap");
        assert_eq!(c.stats().transitions, 2, "total still counts dropped entries");
        assert_eq!(c.phase(), Phase::Executing, "phase still advances");
    }
}
