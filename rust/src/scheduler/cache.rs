//! Plan cache (paper §5 "responsive execution"): plans are indexed by input
//! size; similar input sizes (within a relative tolerance) share a plan —
//! "the memory usages of similar input sizes are similar, and the generated
//! plans are also similar. Therefore, they can also be the plans of each
//! other."

use super::Plan;
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Input-size-indexed plan cache with relative-tolerance matching.
#[derive(Clone, Debug)]
pub struct PlanCache {
    plans: BTreeMap<u64, Plan>,
    tolerance: f64,
    stats: CacheStats,
}

impl PlanCache {
    pub fn new(tolerance: f64) -> Self {
        PlanCache { plans: BTreeMap::new(), tolerance, stats: CacheStats::default() }
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// Look up a plan for `input_size`, accepting any entry whose key is
    /// within ±tolerance (relative). Nearest key wins.
    pub fn lookup(&mut self, input_size: u64) -> Option<Plan> {
        let tol = (input_size as f64 * self.tolerance) as u64;
        let lo = input_size.saturating_sub(tol);
        let hi = input_size.saturating_add(tol);
        let best = self
            .plans
            .range(lo..=hi)
            .min_by_key(|(k, _)| k.abs_diff(input_size))
            .map(|(_, p)| p.clone());
        match best {
            Some(p) => {
                self.stats.hits += 1;
                Some(p)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Exact-key lookup (used with pre-quantised plan sizes).
    pub fn lookup_exact(&mut self, key: u64) -> Option<Plan> {
        match self.plans.get(&key) {
            Some(p) => {
                self.stats.hits += 1;
                Some(p.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, input_size: u64, plan: Plan) {
        self.plans.insert(input_size, plan);
    }

    /// Invalidate everything (e.g. budget changed).
    pub fn clear(&mut self) {
        self.plans.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{ensure, forall};

    #[test]
    fn exact_hit() {
        let mut c = PlanCache::new(0.05);
        c.insert(1000, Plan::of([1, 2]));
        assert_eq!(c.lookup(1000), Some(Plan::of([1, 2])));
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn tolerant_hit_within_5_percent() {
        let mut c = PlanCache::new(0.05);
        c.insert(1000, Plan::of([3]));
        assert!(c.lookup(1040).is_some());
        assert!(c.lookup(960).is_some());
        assert!(c.lookup(1100).is_none());
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn tolerance_boundary_above_key() {
        // Window is relative to the PROBE: [probe - floor(0.05*probe),
        // probe + floor(0.05*probe)]. For key 1000: probe 1052 still spans
        // down to 1000 (tol 52); probe 1053 bottoms out at 1001 — miss.
        let mut c = PlanCache::new(0.05);
        c.insert(1000, Plan::of([1]));
        assert!(c.lookup(1052).is_some(), "probe 1052 reaches key 1000");
        assert!(c.lookup(1053).is_none(), "probe 1053 is just outside");
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn tolerance_boundary_below_key() {
        // From below, probe 953 (tol 47) tops out exactly at 1000 — hit;
        // probe 952 tops out at 999 — miss.
        let mut c = PlanCache::new(0.05);
        c.insert(1000, Plan::of([1]));
        assert!(c.lookup(953).is_some(), "probe 953 reaches key 1000");
        assert!(c.lookup(952).is_none(), "probe 952 is just outside");
    }

    #[test]
    fn lookup_exact_requires_exact_key() {
        let mut c = PlanCache::new(0.05);
        c.insert(1000, Plan::of([4]));
        assert_eq!(c.lookup_exact(1000), Some(Plan::of([4])));
        assert!(c.lookup_exact(1001).is_none(), "no tolerance on the exact path");
        assert!(c.lookup_exact(999).is_none());
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn stats_accounting_and_hit_rate() {
        let mut c = PlanCache::new(0.05);
        assert_eq!(c.stats().hit_rate(), 0.0, "empty stats are a 0 rate, not NaN");
        c.insert(1000, Plan::none());
        let _ = c.lookup(1000); // hit
        let _ = c.lookup(1010); // hit (within 5%)
        let _ = c.lookup(2000); // miss
        let _ = c.lookup_exact(1000); // hit
        let _ = c.lookup_exact(1200); // miss
        assert_eq!(c.stats().hits, 3);
        assert_eq!(c.stats().misses, 2);
        assert!((c.stats().hit_rate() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn insert_same_key_overwrites() {
        let mut c = PlanCache::new(0.05);
        c.insert(500, Plan::of([1]));
        c.insert(500, Plan::of([2]));
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup_exact(500), Some(Plan::of([2])));
    }

    #[test]
    fn zero_tolerance_only_hits_exact() {
        let mut c = PlanCache::new(0.0);
        c.insert(1000, Plan::of([9]));
        assert!(c.lookup(1000).is_some());
        assert!(c.lookup(1001).is_none());
        assert!(c.lookup(999).is_none());
    }

    #[test]
    fn nearest_key_wins() {
        let mut c = PlanCache::new(0.10);
        c.insert(1000, Plan::of([1]));
        c.insert(1080, Plan::of([2]));
        assert_eq!(c.lookup(1070), Some(Plan::of([2])));
    }

    #[test]
    fn clear_resets_entries_not_stats() {
        let mut c = PlanCache::new(0.05);
        c.insert(10, Plan::none());
        let _ = c.lookup(10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn prop_hit_implies_key_within_tolerance() {
        forall(
            23,
            200,
            |r| {
                let keys: Vec<usize> = (0..r.range_u(1, 10)).map(|_| r.range_u(100, 10_000)).collect();
                let probe = r.range_u(100, 10_000);
                (keys, probe)
            },
            |(keys, probe)| {
                let mut c = PlanCache::new(0.05);
                for &k in keys {
                    c.insert(k as u64, Plan::of([k]));
                }
                if let Some(plan) = c.lookup(*probe as u64) {
                    let id = *plan.ids().first().unwrap();
                    let rel = (id as f64 - *probe as f64).abs() / *probe as f64;
                    ensure(rel <= 0.051, &format!("hit key {id} for probe {probe}: rel {rel}"))
                } else {
                    // miss: no key may lie within tolerance
                    for &k in keys {
                        let rel = (k as f64 - *probe as f64).abs() / *probe as f64;
                        ensure(rel > 0.05, &format!("missed key {k} within tol of {probe}"))?;
                    }
                    Ok(())
                }
            },
        );
    }
}
