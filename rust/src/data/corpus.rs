//! Synthetic training corpus for the real PJRT path: token sequences drawn
//! from a Zipf-ish unigram mixture with local bigram structure, so the LM
//! loss has real signal to minimise (Fig 15-style convergence is
//! demonstrable, not flat noise).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub seed: u64,
}

/// Deterministic infinite corpus: next-token-prediction batches.
pub struct Corpus {
    cfg: CorpusConfig,
    rng: Rng,
    /// bigram successor table: tok -> preferred next tokens
    successors: Vec<[u32; 4]>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let successors = (0..cfg.vocab)
            .map(|_| {
                [
                    rng.range_u(0, cfg.vocab - 1) as u32,
                    rng.range_u(0, cfg.vocab - 1) as u32,
                    rng.range_u(0, cfg.vocab - 1) as u32,
                    rng.range_u(0, cfg.vocab - 1) as u32,
                ]
            })
            .collect();
        Corpus { cfg, rng: rng.fork(0xC0FFEE), successors }
    }

    fn zipf_token(&mut self) -> u32 {
        // approximate Zipf by squaring a uniform draw
        let u = self.rng.f64();
        ((u * u * (self.cfg.vocab - 1) as f64) as u32).min(self.cfg.vocab as u32 - 1)
    }

    /// Generate one sequence of `len` tokens.
    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut cur = self.zipf_token();
        for _ in 0..len {
            out.push(cur);
            // 75%: follow bigram structure (learnable); 25%: resample
            cur = if self.rng.f64() < 0.75 {
                let succ = self.successors[cur as usize];
                succ[self.rng.range_u(0, 3)]
            } else {
                self.zipf_token()
            };
        }
        out
    }

    /// Next-token LM batch padded to `pad_to`: (ids, labels), both
    /// row-major [batch, pad_to]. Labels are ids shifted left by one.
    pub fn lm_batch(&mut self, batch: usize, seqlen: usize, pad_to: usize) -> (Vec<i32>, Vec<i32>) {
        assert!(pad_to >= seqlen);
        let mut ids = Vec::with_capacity(batch * pad_to);
        let mut labels = Vec::with_capacity(batch * pad_to);
        for _ in 0..batch {
            let seq = self.sequence(seqlen + 1);
            for t in 0..pad_to {
                if t < seqlen {
                    ids.push(seq[t] as i32);
                    labels.push(seq[t + 1] as i32);
                } else {
                    ids.push(0);
                    labels.push(0);
                }
            }
        }
        (ids, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig { vocab: 512, seed: 9 })
    }

    #[test]
    fn tokens_in_vocab() {
        let mut c = corpus();
        let seq = c.sequence(1000);
        assert!(seq.iter().all(|&t| (t as usize) < 512));
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // successor-following makes P(next | cur) far from uniform
        let mut c = corpus();
        let seq = c.sequence(20_000);
        let mut follows = 0usize;
        for w in seq.windows(2) {
            if c.successors[w[0] as usize].contains(&w[1]) {
                follows += 1;
            }
        }
        let rate = follows as f64 / (seq.len() - 1) as f64;
        assert!(rate > 0.5, "bigram-follow rate {rate}");
    }

    #[test]
    fn lm_batch_shapes_and_shift() {
        let mut c = corpus();
        let (ids, labels) = c.lm_batch(3, 10, 16);
        assert_eq!(ids.len(), 3 * 16);
        assert_eq!(labels.len(), 3 * 16);
        // padding region is zero
        assert!(ids[10..16].iter().all(|&x| x == 0));
        // shift property within the sequence region (row 0)
        // labels[t] should equal ids[t+1] for t < seqlen-1
        for t in 0..9 {
            assert_eq!(labels[t], ids[t + 1]);
        }
    }

    #[test]
    fn deterministic() {
        let a = corpus().sequence(64);
        let b = corpus().sequence(64);
        assert_eq!(a, b);
    }
}
