//! `mimose` — leader entrypoint / CLI launcher.
//!
//! Subcommands:
//!   sim|run  run one simulated experiment (task x planner x budget)
//!   sweep    planner comparison across budgets for a task
//!   plan     inspect the plan Mimose would generate for a given input
//!   fleet    run N jobs time-sharing one budget through the broker
//!   info     print model/task/artifact inventory
//!
//! Tasks: the paper's Table 1 set (mc-roberta, qa-xlnet, qa-bert, tc-bert)
//! plus the stage-graph extensions: seq2seq (encoder-decoder, independent
//! src/tgt lengths), swin (resolution-augmented vision), and unet
//! (multi-branch segmentation — a skip branch/join pair per resolution).
//! Planners: the §6.1 set (baseline, sublinear, dtr, mimose) plus the
//! offline `optimal` oracle (exact minimum-recompute plans).
//!
//! Examples:
//!   mimose sim --task tc-bert --planner mimose --budget-gb 6 --iters 1000
//!   mimose run --task seq2seq --planner mimose --budget-gb 4 --iters 200
//!   mimose sim --config experiment.toml
//!   mimose sweep --task qa-bert --lo 4 --hi 7 --points 4
//!   mimose plan --task tc-bert --budget-gb 5 --seqlen 300
//!   mimose plan --task seq2seq --budget-gb 4 --seqlen 300 --tgt-seqlen 250
//!   mimose fleet --tasks tc-bert,qa-bert,mc-roberta --budget-gb 16 --compare
//!   mimose fleet --tasks tc-bert,qa-bert --weights 3.0,1.0 --events events.toml

use mimose::config::{
    toml::Doc, CoordinatorConfig, ExperimentConfig, FleetConfig, FleetEvent, JobSpec,
    MimoseConfig, ObsConfig, Pacing, Placement, PlannerKind, Task,
};
use mimose::coordinator::{observations_from_profile, Coordinator, Phase};
use mimose::engine::sim::{input_for, max_task_profile, SimEngine};
use mimose::fleet::{FleetReport, FleetScheduler};
use mimose::metrics::RunReport;
use mimose::model::task_profile;
use mimose::planners::IterationMode;
use mimose::util::cli::Cli;
use mimose::util::{fmt_bytes, GIB};

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if args.is_empty() || args[0].starts_with('-') {
        "help".to_string()
    } else {
        args.remove(0)
    };
    match cmd.as_str() {
        // `run` is the ergonomic alias: `mimose run --task seq2seq ...`
        "sim" | "run" => cmd_sim(&args),
        "sweep" => cmd_sweep(&args),
        "plan" => cmd_plan(&args),
        "fleet" => cmd_fleet(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "mimose — input-aware checkpointing planner (paper reproduction)\n\n\
                 subcommands:\n  sim|run run one simulated experiment\n  \
                 sweep   compare planners across budgets\n  \
                 plan    inspect a Mimose plan for an input size\n  \
                 fleet   N jobs time-sharing one budget (broker arbitration)\n  \
                 info    model/task/artifact inventory\n\n\
                 `mimose <cmd> --help` for options; real training lives in\n\
                 `cargo run --release --example train_e2e`."
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

fn parse_or_exit(cli: Cli, args: &[String]) -> Cli {
    match cli.parse_from(args) {
        Ok(c) => c,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn report_summary(r: &RunReport) {
    println!("  iterations        : {}", r.iters.len());
    println!("  epoch time (sim)  : {:.2} s", r.total_ms() / 1e3);
    println!("  mean iteration    : {:.1} ms", r.mean_iter_ms());
    println!("  recompute share   : {:.2}%", r.recompute_share() * 100.0);
    println!("  planning share    : {:.3}%", r.planning_share() * 100.0);
    println!("  collector total   : {:.1} ms", r.collector_ms());
    println!("  cache hit rate    : {:.1}%", r.cache_hit_rate() * 100.0);
    println!(
        "  phases            : {} sheltered / {} frozen / {} executing / {} reactive",
        r.phase_count(Phase::Sheltered),
        r.phase_count(Phase::Frozen),
        r.phase_count(Phase::Executing),
        r.phase_count(Phase::Reactive),
    );
    if r.phase_count(Phase::Frozen) > 0 {
        println!(
            "  replan latency    : {:.3} ms mean / {:.3} ms max",
            r.replan_ms_mean(),
            r.replan_ms_max()
        );
    }
    println!("  peak memory       : {}", fmt_bytes(r.peak_bytes()));
    println!("  max fragmentation : {}", fmt_bytes(r.max_frag_bytes()));
    println!("  OOM failures      : {}", r.oom_failures());
}

/// Print the Coordinator's phase-transition log (first `max` entries).
fn report_transitions(c: &Coordinator, max: usize) {
    let ts = c.transitions();
    if ts.is_empty() {
        return;
    }
    let s = c.stats();
    println!("  phase transitions ({} total, {} recorded):", s.transitions, ts.len());
    for t in ts.iter().take(max) {
        println!("    iter {:>5}: {} -> {} (input size {})", t.iter, t.from, t.to, t.input_size);
    }
    if ts.len() > max {
        println!("    ... {} more recorded", ts.len() - max);
    }
    println!(
        "  coordinator       : {} plans generated, {} reshelters, {} cached sizes",
        s.plans_generated, s.reshelters, s.cache_entries
    );
}

/// Print the obs counter summary and write the Chrome trace, if either
/// facility was enabled for this run.
fn report_obs(obs: &ObsConfig) {
    if obs.enabled {
        let nonzero: Vec<(String, u64)> =
            mimose::obs::counters().into_iter().filter(|(_, v)| *v > 0).collect();
        if !nonzero.is_empty() {
            println!("  obs counters      :");
            for (name, v) in &nonzero {
                println!("    {name:<28} {v}");
            }
        }
        let v = mimose::obs::counter_value;
        let (hits, misses) = (v("plan_cache.hits"), v("plan_cache.misses"));
        if hits + misses > 0 {
            println!(
                "    plan-cache hit rate          {:.1}%",
                100.0 * hits as f64 / (hits + misses) as f64
            );
        }
        let (full, incr) = (v("broker.path_full"), v("broker.path_incremental"));
        if full + incr > 0 {
            println!(
                "    broker incremental ratio     {:.1}%",
                100.0 * incr as f64 / (full + incr) as f64
            );
        }
    }
    if !obs.trace_out.is_empty() {
        match mimose::obs::write_trace(&obs.trace_out) {
            Ok(()) => println!(
                "  trace             : {} events -> {}",
                mimose::obs::trace_len(),
                obs.trace_out
            ),
            Err(e) => eprintln!("cannot write trace '{}': {e}", obs.trace_out),
        }
    }
}

fn cmd_sim(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("mimose sim", "run one simulated experiment")
            .opt("config", "", "TOML config path (overrides other flags)")
            .opt("task", "tc-bert", "mc-roberta | qa-xlnet | qa-bert | tc-bert | seq2seq | swin | unet")
            .opt("planner", "mimose", "baseline | sublinear | dtr | mimose | optimal (oracle)")
            .opt("budget-gb", "6.0", "memory budget (GiB)")
            .opt("iters", "1000", "iterations (0 = full epoch)")
            .opt("seed", "42", "rng seed")
            .opt("collect-iters", "10", "Mimose sheltered iterations")
            .opt("reserve-gb", "1.0", "Mimose fragmentation reserve (GiB)")
            .flag("reshelter", "re-collect novel input sizes after warmup (§4.2)")
            .flag("obs", "enable the metrics registry (report + TSV obs columns)")
            .opt("trace-out", "", "write a Chrome trace-event JSON file (implies tracing)")
            .opt("tsv", "", "append a TSV row to this file"),
        args,
    );
    let mut cfg = if !cli.get("config").is_empty() {
        ExperimentConfig::from_file(&cli.get("config")).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        })
    } else {
        let task = Task::parse(&cli.get("task")).unwrap_or_else(|| {
            eprintln!("unknown task");
            std::process::exit(2);
        });
        let planner = PlannerKind::parse(&cli.get("planner")).unwrap_or_else(|| {
            eprintln!("unknown planner");
            std::process::exit(2);
        });
        let mut c = ExperimentConfig::new(task, planner, cli.get_f64("budget-gb"));
        c.max_iters = cli.get_usize("iters");
        c.seed = cli.get_u64("seed");
        c.mimose = MimoseConfig {
            collect_iters: cli.get_usize("collect-iters"),
            reserve_bytes: (cli.get_f64("reserve-gb") * GIB as f64) as u64,
            ..Default::default()
        };
        c.coordinator = CoordinatorConfig {
            reshelter_on_novel: cli.get_flag("reshelter"),
            ..Default::default()
        };
        c
    };
    if cli.get_flag("obs") {
        cfg.obs.enabled = true;
    }
    if !cli.get("trace-out").is_empty() {
        cfg.obs.trace_out = cli.get("trace-out");
    }
    cfg.obs.apply();
    println!(
        "sim: {} / {} @ {:.1} GB (seed {})",
        cfg.task.name(),
        cfg.planner.name(),
        cfg.budget_gb(),
        cfg.seed
    );
    let obs_cfg = cfg.obs.clone();
    match SimEngine::new(cfg) {
        Ok(mut e) => {
            let r = e.run_epoch();
            report_summary(&r);
            if let Some(c) = e.coordinator() {
                report_transitions(c, 8);
            }
            report_obs(&obs_cfg);
            let tsv = cli.get("tsv");
            if !tsv.is_empty() {
                let new = !std::path::Path::new(&tsv).exists();
                let mut header = RunReport::tsv_header().to_string();
                let mut row = r.tsv_row();
                if obs_cfg.enabled {
                    // obs columns ride along the report row (the pinned
                    // RunReport TSV schema itself is untouched)
                    header.push_str(
                        "\tobs_plan_cache_hits\tobs_plan_cache_misses\
                         \tobs_estimator_refits\tobs_fwd_stages\tobs_recompute_stages",
                    );
                    let v = mimose::obs::counter_value;
                    row.push_str(&format!(
                        "\t{}\t{}\t{}\t{}\t{}",
                        v("plan_cache.hits"),
                        v("plan_cache.misses"),
                        v("estimator.refits"),
                        v("engine.fwd_stages"),
                        v("engine.recompute_stages")
                    ));
                }
                let mut out = String::new();
                if new {
                    out.push_str(&header);
                    out.push('\n');
                }
                out.push_str(&row);
                out.push('\n');
                use std::io::Write;
                let mut f = std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&tsv)
                    .expect("open tsv");
                f.write_all(out.as_bytes()).expect("write tsv");
                println!("  appended -> {tsv}");
            }
        }
        Err(e) => {
            eprintln!("cannot run: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_sweep(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("mimose sweep", "planner comparison across budgets")
            .opt("task", "tc-bert", "task name")
            .opt("lo", "4.0", "lowest budget (GiB)")
            .opt("hi", "8.0", "highest budget (GiB)")
            .opt("points", "5", "budget points")
            .opt("iters", "500", "iterations per run"),
        args,
    );
    let task = Task::parse(&cli.get("task")).expect("unknown task");
    let iters = cli.get_usize("iters");
    let mut bcfg = ExperimentConfig::new(task, PlannerKind::Baseline, 64.0);
    bcfg.max_iters = iters;
    let base = SimEngine::new(bcfg).unwrap().run_epoch().total_ms();
    println!("{} — epoch time normalised to Baseline\n", task.name());
    println!("budget     sublinear      dtr   mimose");
    let (lo, hi, points) = (cli.get_f64("lo"), cli.get_f64("hi"), cli.get_usize("points").max(2));
    for p in 0..points {
        let budget = lo + (hi - lo) * p as f64 / (points - 1) as f64;
        print!("{budget:5.1} GB ");
        for kind in [PlannerKind::Sublinear, PlannerKind::Dtr, PlannerKind::Mimose] {
            let mut cfg = ExperimentConfig::new(task, kind, budget);
            cfg.max_iters = iters;
            match SimEngine::new(cfg) {
                Ok(mut e) => {
                    let r = e.run_epoch();
                    if r.oom_failures() > 0 {
                        print!("      OOM");
                    } else {
                        print!("   {:6.3}", r.total_ms() / base);
                    }
                }
                Err(_) => print!("   no-fit"),
            }
        }
        println!();
    }
}

fn cmd_plan(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("mimose plan", "inspect the plan for one input shape")
            .opt("task", "tc-bert", "task name (incl. seq2seq, swin, unet)")
            .opt("budget-gb", "5.0", "memory budget (GiB)")
            .opt("seqlen", "300", "collated seqlen (resolution for swin; src for seq2seq)")
            .opt("tgt-seqlen", "0", "collated target seqlen (seq2seq; 0 = same as --seqlen)")
            .opt("seed", "1", "collector sampling seed"),
        args,
    );
    let task = Task::parse(&cli.get("task")).expect("unknown task");
    let budget = (cli.get_f64("budget-gb") * GIB as f64) as u64;
    let n_stages = max_task_profile(task).layers().len();
    let mut coord = Coordinator::new(
        budget,
        n_stages,
        MimoseConfig::default(),
        CoordinatorConfig::default(),
    );

    // sheltered execution over the task's own input distribution
    let mut stream = mimose::data::InputStream::new(task, cli.get_u64("seed"));
    while !coord.collector().is_frozen() {
        let shape = stream.next_shape();
        let profile = task_profile(task, task.batch(), shape.0, shape.1);
        let input = input_for(task, shape);
        if let IterationMode::Sheltered(_) = coord.begin_iteration(&input, &profile).mode {
            let obs = observations_from_profile(&profile, &input, |flops| flops as f64 / 1e9);
            coord.end_iteration(&input, &obs, 1.0);
        }
    }

    let seq = cli.get_usize("seqlen");
    let tgt = cli.get_usize("tgt-seqlen");
    let profile = task_profile(task, task.batch(), seq, tgt);
    let input = input_for(task, (seq, tgt));
    let d = coord.begin_iteration(&input, &profile);
    let key = input.key();
    if key.is_2d() {
        println!(
            "{} @ {:.1} GB, src {seq} x tgt {} (input key {} x {}):",
            task.name(),
            budget as f64 / GIB as f64,
            profile.seqlen2,
            key.primary,
            key.secondary
        );
    } else {
        println!(
            "{} @ {:.1} GB, seqlen {seq} (input size {}):",
            task.name(),
            budget as f64 / GIB as f64,
            input.size()
        );
    }
    let g = &profile.graph;
    println!(
        "  stage graph   : {} stages, {} branch points, {} joins{}",
        g.len(),
        g.branch_points().len(),
        g.join_points().len(),
        if g.is_chain() { " (chain)" } else { "" }
    );
    println!("  planning time : {:.3} ms (cache {})", d.planning_ms, if d.cache_hit { "hit" } else { "miss" });
    if let IterationMode::Planned(plan) = d.mode {
        println!("  checkpointed  : {} stages {:?}", plan.len(), plan.ids());
        println!("  kept activations: {}", fmt_bytes(profile.planned_act_bytes(&plan.ids())));
        println!("  no-plan need    : {}", fmt_bytes(profile.total_act_bytes()));
        println!("  est. peak       : {}", fmt_bytes(profile.peak_bytes(&plan.ids())));
        println!("  recompute extra : {:.1}% of fwd FLOPs",
                 100.0 * profile.recompute_flops(&plan.ids()) as f64 / profile.fwd_flops() as f64);
    }
}

fn report_fleet(r: &FleetReport) {
    println!(
        "  mode              : {}",
        if r.arbitrated { "arbitrated (broker)" } else { "static equal split" }
    );
    println!(
        "  {:<16} {:>4} {:>11} {:>6} {:>12} {:>10} {:>8} {:>7} {:>8} {:>11}",
        "job", "w", "lifetime", "steps", "sim time s", "peak", "cache%", "shared", "rebinds",
        "final budget"
    );
    for j in &r.jobs {
        println!(
            "  {:<16} {:>4.1} {:>11} {:>6} {:>12.2} {:>10} {:>7.1}% {:>7} {:>8} {:>11}",
            j.name,
            j.weight,
            j.lifetime_label(),
            j.steps,
            j.total_ms / 1e3,
            fmt_bytes(j.peak_bytes),
            j.cache_hit_rate * 100.0,
            j.shared_hits,
            j.budget_changes,
            fmt_bytes(j.final_budget),
        );
    }
    if r.arrived_jobs() + r.departed_jobs() > 0 {
        println!(
            "  dynamics          : {} arrivals, {} departures/completions",
            r.arrived_jobs(),
            r.departed_jobs()
        );
    }
    if r.preemptions + r.shocks + r.forced_stops > 0 {
        println!(
            "  chaos             : {} preemption notices, {} budget shocks, {} forced stops",
            r.preemptions, r.shocks, r.forced_stops
        );
    }
    println!("  weighted fairness : {:.3} mean Jain over multi-tenant rounds", r.weighted_jain_mean());
    println!(
        "  aggregate peak    : {} of {} global ({})",
        fmt_bytes(r.max_aggregate_peak()),
        fmt_bytes(r.global_budget),
        if r.budget_respected() { "respected" } else { "EXCEEDED" },
    );
    let bms = r.broker_ms();
    if bms.count() > 0 {
        println!(
            "  broker            : {} decisions, {} overshoots resolved, {:.4} ms mean / {:.4} ms max",
            bms.count(),
            r.overshoots,
            bms.mean(),
            bms.max()
        );
    }
    println!(
        "  shared cache      : {} cross-job hits, {} entries",
        r.shared_cache_hits, r.shared_cache_entries
    );
    if r.devices > 1 {
        println!(
            "  placement         : {} arrivals placed, {:.1}% onto a warm plan cache",
            r.placements,
            r.placement_warm_hit_rate() * 100.0
        );
        for d in 0..r.devices {
            let peak = r.device_rounds(d).map(|dec| dec.aggregate_peak).max().unwrap_or(0);
            let decisions = r.device_rounds(d).count();
            println!(
                "  {:<18}: {} budget, peak {}, {} broker decisions",
                format!("device {d}"),
                fmt_bytes(r.device_globals[d]),
                fmt_bytes(peak),
                decisions
            );
        }
        println!(
            "  migrations        : {} ({} iterations lost in transit)",
            r.migrations, r.migration_lost_iters
        );
    }
    // the warm-start pin: a fleet restarted from a persisted plan cache
    // reports 0 here (the CI smoke greps this line)
    let sheltered: usize = r.jobs.iter().map(|j| j.sheltered_iters).sum();
    println!("  sheltered iters   : {sheltered}");
    println!("  OOM failures      : {}", r.oom_failures());
    println!("  fleet throughput  : {:.2} iters/s (simulated)", r.throughput_iters_per_s());
}

fn cmd_fleet(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("mimose fleet", "jobs time-sharing one memory budget")
            .opt("config", "", "TOML config path with a [fleet] section")
            .opt("tasks", "tc-bert,qa-bert", "comma-separated task list (tasks may repeat)")
            .opt(
                "weights",
                "",
                "comma-separated priority weights aligned with --tasks (default all 1.0)",
            )
            .opt(
                "events",
                "",
                "TOML path whose [[fleet.events]] script mid-run arrivals/departures",
            )
            .opt(
                "shock-at",
                "",
                "budget shocks 'round:gb[,round:gb...]' rebinding the global mid-run",
            )
            .opt(
                "preempt",
                "",
                "preemption notices 'job:round[:drain][,...]' (drain rounds default 1)",
            )
            .opt("budget-gb", "16.0", "GLOBAL memory budget shared by all jobs (GiB)")
            .opt("floor-gb", "2.0", "configured per-job guaranteed floor (GiB)")
            .opt("steps", "200", "interleaved rounds (iterations per job)")
            .opt("seed", "42", "base rng seed (the job with id i uses seed+i)")
            .opt("grid-mb", "128", "broker allocation granularity (MiB)")
            .opt("cache-capacity", "512", "shared plan-cache capacity (0 = unbounded)")
            .opt("pacing", "", "event pacing: rounds | lockstep | profiled (default: config)")
            .opt("tick-ms", "", "scripted-round tick length in ms (profiled pacing only)")
            .opt("devices", "", "devices the global budget splits across (default 1)")
            .opt(
                "placement",
                "",
                "arrival placement for multi-device fleets: first-fit | least-loaded | warm",
            )
            .opt(
                "migrate-after",
                "",
                "consecutive overshoot fills before a device migrates a tenant (0 = never)",
            )
            .opt("migration-cost", "", "iterations a migrated tenant loses in transit")
            .opt(
                "plan-threads",
                "",
                "cohort-parallel planning workers (0 = one per core, 1 = serial)",
            )
            .opt(
                "cache-in",
                "",
                "warm-start: load the shared plan cache from this file (missing/stale = cold)",
            )
            .opt(
                "cache-out",
                "",
                "persist the shared plan cache to this file after the run",
            )
            .flag("no-shared-cache", "disable cross-job plan reuse")
            .flag("equal-split", "static equal split instead of broker arbitration")
            .flag("compare", "also run the other mode and print the speedup")
            .flag("obs", "enable the metrics registry (broker/cache/engine counters)")
            .opt(
                "trace-out",
                "",
                "write a Chrome trace-event JSON (one track per job + broker track)",
            ),
        args,
    );
    let mut cfg = if !cli.get("config").is_empty() {
        if !cli.get("weights").is_empty() {
            // --events composes with --config (it appends), but weights are
            // per-job attributes of the config's own job list — silently
            // ignoring the flag would fake a priority fill
            eprintln!(
                "--weights applies to --tasks; with --config, set 'weight' in [[fleet.jobs]]"
            );
            std::process::exit(2);
        }
        FleetConfig::from_file(&cli.get("config")).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        })
    } else {
        let tasks: Vec<Task> = cli
            .get("tasks")
            .split(',')
            .map(|s| {
                Task::parse(s.trim()).unwrap_or_else(|| {
                    eprintln!("unknown task '{s}'");
                    std::process::exit(2);
                })
            })
            .collect();
        let mut jobs = JobSpec::from_tasks(&tasks);
        let weights = cli.get("weights");
        if !weights.is_empty() {
            let ws: Vec<f64> = weights
                .split(',')
                .map(|s| {
                    s.trim().parse::<f64>().unwrap_or_else(|_| {
                        eprintln!("bad weight '{s}'");
                        std::process::exit(2);
                    })
                })
                .collect();
            if ws.len() != jobs.len() {
                eprintln!("--weights needs one value per task ({} != {})", ws.len(), jobs.len());
                std::process::exit(2);
            }
            for (job, w) in jobs.iter_mut().zip(ws) {
                job.weight = w;
            }
        }
        FleetConfig {
            global_budget_bytes: (cli.get_f64("budget-gb") * GIB as f64) as u64,
            floor_bytes: (cli.get_f64("floor-gb") * GIB as f64) as u64,
            steps: cli.get_usize("steps"),
            shared_cache: !cli.get_flag("no-shared-cache"),
            cache_capacity: cli.get_usize("cache-capacity"),
            grid_bytes: (cli.get_f64("grid-mb") * (1u64 << 20) as f64) as u64,
            arbitrated: !cli.get_flag("equal-split"),
            jobs,
            seed: cli.get_u64("seed"),
            ..Default::default()
        }
    };
    if !cli.get("events").is_empty() {
        let text = std::fs::read_to_string(cli.get("events")).unwrap_or_else(|e| {
            eprintln!("cannot read events file: {e}");
            std::process::exit(2);
        });
        let doc = Doc::parse(&text).unwrap_or_else(|e| {
            eprintln!("events file error: {e}");
            std::process::exit(2);
        });
        match FleetConfig::events_from_doc(&doc) {
            Ok(evs) => cfg.events.extend(evs),
            Err(e) => {
                eprintln!("events file error: {e}");
                std::process::exit(2);
            }
        }
    }
    let shock_arg = cli.get("shock-at");
    if !shock_arg.is_empty() {
        for part in shock_arg.split(',') {
            let bad = || -> ! {
                eprintln!("--shock-at wants 'round:gb[,round:gb...]', got '{part}'");
                std::process::exit(2);
            };
            let (round, gb) = part.trim().split_once(':').unwrap_or_else(|| bad());
            let at_round = round.trim().parse::<usize>().unwrap_or_else(|_| bad());
            let gb = gb.trim().parse::<f64>().unwrap_or(f64::NAN);
            if !gb.is_finite() || gb <= 0.0 {
                bad();
            }
            cfg.events.push(FleetEvent::Shock {
                at_round,
                global_budget_bytes: (gb * GIB as f64) as u64,
            });
        }
    }
    let preempt_arg = cli.get("preempt");
    if !preempt_arg.is_empty() {
        for part in preempt_arg.split(',') {
            let bad = || -> ! {
                eprintln!("--preempt wants 'job:round[:drain][,...]', got '{part}'");
                std::process::exit(2);
            };
            let mut fields = part.trim().split(':');
            let job = fields.next().unwrap_or_default().trim().to_string();
            let round = fields.next().unwrap_or_else(|| bad());
            let at_round = round.trim().parse::<usize>().unwrap_or_else(|_| bad());
            let drain_rounds = match fields.next() {
                Some(d) => d.trim().parse::<usize>().unwrap_or_else(|_| bad()),
                None => 1,
            };
            if job.is_empty() || fields.next().is_some() {
                bad();
            }
            cfg.events.push(FleetEvent::Preempt { job, at_round, drain_rounds });
        }
    }
    let pacing_arg = cli.get("pacing");
    if !pacing_arg.is_empty() {
        cfg.pacing = Pacing::parse(&pacing_arg).unwrap_or_else(|| {
            eprintln!("unknown pacing '{pacing_arg}' (rounds | lockstep | profiled)");
            std::process::exit(2);
        });
    }
    let tick_arg = cli.get("tick-ms");
    if !tick_arg.is_empty() {
        let tick = tick_arg.parse::<f64>().unwrap_or(f64::NAN);
        if !tick.is_finite() || tick <= 0.0 {
            eprintln!("--tick-ms must be a positive number, got '{tick_arg}'");
            std::process::exit(2);
        }
        cfg.tick_ms = tick;
    }
    if !cli.get("devices").is_empty() {
        cfg.devices = cli.get_usize("devices");
    }
    let placement_arg = cli.get("placement");
    if !placement_arg.is_empty() {
        cfg.placement = Placement::parse(&placement_arg).unwrap_or_else(|| {
            eprintln!("unknown placement '{placement_arg}' (first-fit | least-loaded | warm)");
            std::process::exit(2);
        });
    }
    if !cli.get("migrate-after").is_empty() {
        cfg.migrate_after = cli.get_usize("migrate-after");
    }
    if !cli.get("migration-cost").is_empty() {
        cfg.migration_cost_iters = cli.get_usize("migration-cost");
    }
    if cli.get_flag("obs") {
        cfg.obs.enabled = true;
    }
    if !cli.get("trace-out").is_empty() {
        cfg.obs.trace_out = cli.get("trace-out");
    }
    if !cli.get("plan-threads").is_empty() {
        cfg.plan_threads = cli.get_usize("plan-threads");
    }
    // --cache-in overrides the TOML's [mimose] cache_path for loading;
    // --cache-out overrides it for saving (the TOML path serves both roles)
    if !cli.get("cache-in").is_empty() {
        cfg.mimose.cache_path = cli.get("cache-in");
    }
    let cache_out = if !cli.get("cache-out").is_empty() {
        cli.get("cache-out")
    } else {
        cfg.mimose.cache_path.clone()
    };
    cfg.obs.apply();
    let run_mode = |arbitrated: bool, cache_out: &str| -> FleetReport {
        let mut c = cfg.clone();
        c.arbitrated = arbitrated;
        match FleetScheduler::new(c) {
            Ok(mut f) => {
                if f.warm_loaded() {
                    println!("  plan cache        : warm start from {}", cfg.mimose.cache_path);
                }
                let r = f.run();
                if !cache_out.is_empty() {
                    match f.save_cache(cache_out) {
                        Ok(()) => println!("  plan cache        : saved to {cache_out}"),
                        Err(e) => {
                            eprintln!("cannot save plan cache to {cache_out}: {e}");
                            std::process::exit(1);
                        }
                    }
                }
                r
            }
            Err(e) => {
                eprintln!("cannot run fleet: {e}");
                std::process::exit(1);
            }
        }
    };
    if cfg.devices > 1 {
        println!(
            "fleet: {} initial jobs, {} scripted events, sharing {:.1} GB across {} devices \
             ({} placement, {} pacing, seed {})",
            cfg.jobs.len(),
            cfg.events.len(),
            cfg.global_budget_gb(),
            cfg.devices,
            cfg.placement.name(),
            cfg.pacing.name(),
            cfg.seed
        );
    } else {
        println!(
            "fleet: {} initial jobs, {} scripted events, sharing {:.1} GB ({} pacing, seed {})",
            cfg.jobs.len(),
            cfg.events.len(),
            cfg.global_budget_gb(),
            cfg.pacing.name(),
            cfg.seed
        );
    }
    let r = run_mode(cfg.arbitrated, &cache_out);
    report_fleet(&r);
    report_obs(&cfg.obs);
    if cli.get_flag("compare") {
        // the comparison run never saves: the primary mode's cache wins
        let other = run_mode(!cfg.arbitrated, "");
        println!("\n--- comparison mode ---");
        report_fleet(&other);
        let (fleet_r, equal_r) =
            if cfg.arbitrated { (&r, &other) } else { (&other, &r) };
        let speedup = equal_r.total_ms() / fleet_r.total_ms().max(1e-9);
        println!(
            "\narbitrated vs equal split: {:.2} vs {:.2} iters/s -> {:.3}x speedup",
            fleet_r.throughput_iters_per_s(),
            equal_r.throughput_iters_per_s(),
            speedup
        );
    }
}

fn cmd_info(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("mimose info", "model/task/artifact inventory")
            .opt("artifacts", "artifacts", "artifacts directory"),
        args,
    );
    println!("tasks (paper Table 1 + stage-graph extensions):");
    for t in Task::extended() {
        let m = t.model();
        let p = max_task_profile(t);
        let shape = if let Some(r2) = t.seq2_range() {
            format!("src {:?} x tgt {:?}", t.seq_range(), r2)
        } else {
            format!("seq {:?}", t.seq_range())
        };
        println!(
            "  {:<12} model {:<15} batch {:<3} {:<28} {:>2} stages, fixed {}",
            t.name(),
            m.name,
            t.batch(),
            shape,
            p.layers().len(),
            fmt_bytes(p.fixed_bytes),
        );
    }
    let dir = std::path::Path::new(&cli.get("artifacts")).to_path_buf();
    match mimose::runtime::load_manifest(&dir) {
        Ok(m) => {
            println!("\nAOT artifacts ({}):", dir.display());
            for (name, cfg) in &m {
                println!(
                    "  {:<10} {} artifacts, buckets {:?}, {:.1}M params",
                    name,
                    cfg.artifacts.len(),
                    cfg.seq_buckets,
                    cfg.param_count as f64 / 1e6
                );
            }
        }
        Err(e) => println!("\n(no artifacts: {e})"),
    }
}
