//! The Mimose planner (paper §4): shuttling collector + lightning estimator
//! + responsive scheduler + plan cache, composed behind the `Planner` trait.
//!
//! Timeline per §4.1: iterations in *sheltered execution* run the
//! conservative plan and collect per-layer data; once the collector freezes
//! the estimator is trained and *responsive execution* begins — cache lookup
//! first, Algorithm 1 on miss, all in well under a millisecond (Table 2).

use super::{
    checkpointable, usable_activation_budget, InputDesc, IterationMode, PlanDecision, Planner,
};
use crate::collector::{Collector, Observation};
use crate::config::MimoseConfig;
use crate::estimator::MemoryEstimator;
use crate::model::{LayerKind, ModelProfile};
use crate::scheduler::{greedy_schedule, LayerEst, Plan, PlanCache};
use crate::util::timer::Timer;

/// Round `size` up to the next point of a geometric grid with step
/// `(1 + tol)` — all sizes in one grid cell share one (conservative) plan.
pub fn quantize_up(size: u64, tol: f64) -> u64 {
    if size == 0 {
        return 0;
    }
    let step = (1.0 + tol.max(1e-6)).ln();
    let cell = ((size as f64).ln() / step).ceil();
    (cell * step).exp().ceil() as u64
}

pub struct MimosePlanner {
    cfg: MimoseConfig,
    budget: u64,
    collector: Collector,
    estimator: MemoryEstimator,
    cache: PlanCache,
    /// Estimator training time (once, at the sheltered->responsive switch).
    pub train_ms: f64,
    /// Total estimator+scheduler time across the run (Table 2 column).
    pub plan_ms_total: f64,
    /// Number of plans generated (cache misses that ran Algorithm 1).
    pub plans_generated: u64,
    estimator_ready: bool,
}

impl MimosePlanner {
    pub fn new(budget: u64, n_layers: usize, cfg: MimoseConfig) -> Self {
        MimosePlanner {
            collector: Collector::new(cfg.collect_iters),
            estimator: MemoryEstimator::new(n_layers),
            cache: PlanCache::new(cfg.cache_tolerance),
            cfg,
            budget,
            train_ms: 0.0,
            plan_ms_total: 0.0,
            plans_generated: 0,
            estimator_ready: false,
        }
    }

    pub fn collector(&self) -> &Collector {
        &self.collector
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    pub fn estimator(&self) -> &MemoryEstimator {
        &self.estimator
    }

    /// Conservative plan for sheltered execution: checkpoint every block
    /// (the Sublinear-style envelope of §4.2 — memory footprint equals the
    /// static planner's while we measure).
    fn conservative_plan(profile: &ModelProfile) -> Plan {
        Plan::of(
            profile
                .layers
                .iter()
                .filter(|l| l.kind != LayerKind::Head && l.savings() > 0)
                .map(|l| l.id),
        )
    }

    /// Algorithm 1 over *estimated* per-layer bytes.
    fn generate_plan(&mut self, input_size: u64, profile: &ModelProfile) -> Plan {
        let layers: Vec<LayerEst> = checkpointable(profile)
            .into_iter()
            .map(|mut l| {
                l.est_bytes = self.estimator.predict_bytes(l.id, input_size as f64) as u64;
                l
            })
            .collect();
        let est_total: u64 = layers.iter().map(|l| l.est_bytes).sum();
        let usable = usable_activation_budget(self.budget, profile, self.cfg.reserve_bytes);
        let excess = est_total.saturating_sub(usable);
        greedy_schedule(&layers, excess, self.cfg.bucket_tolerance)
    }
}

impl Planner for MimosePlanner {
    fn name(&self) -> &'static str {
        "mimose"
    }

    fn begin_iteration(&mut self, input: &InputDesc, profile: &ModelProfile) -> PlanDecision {
        let size = input.size();
        // Quantise the planning size UP to the cache grid so that a cached
        // plan is always conservative for every input mapped to it (a plan
        // generated for a slightly smaller input could under-checkpoint).
        let plan_size = quantize_up(size, self.cfg.cache_tolerance);

        // ---- sheltered execution ----
        if self.collector.wants_collection(size) {
            return PlanDecision {
                mode: IterationMode::Sheltered(Self::conservative_plan(profile)),
                planning_ms: 0.0,
                cache_hit: false,
            };
        }

        // ---- responsive execution ----
        let t = Timer::start();
        if !self.estimator_ready {
            self.train_ms = self.estimator.train();
            self.estimator_ready = true;
        }
        if let Some(plan) = self.cache.lookup_exact(plan_size) {
            let planning_ms = t.elapsed_ms();
            self.plan_ms_total += planning_ms;
            return PlanDecision { mode: IterationMode::Planned(plan), planning_ms, cache_hit: true };
        }
        let plan = self.generate_plan(plan_size, profile);
        self.cache.insert(plan_size, plan.clone());
        self.plans_generated += 1;
        let planning_ms = t.elapsed_ms();
        self.plan_ms_total += planning_ms;
        PlanDecision { mode: IterationMode::Planned(plan), planning_ms, cache_hit: false }
    }

    fn end_iteration(&mut self, input: &InputDesc, obs: &[Observation], extra_fwd_ms: f64) {
        if !self.collector.is_frozen() && !obs.is_empty() {
            self.collector.ingest(&mut self.estimator, input.size(), obs, extra_fwd_ms);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::model::transformer_profile;
    use crate::util::rng::Rng;
    use crate::util::GIB;

    fn spec() -> ModelSpec {
        ModelSpec::bert_base()
    }

    /// Drive the planner through sheltered execution with synthetic
    /// observations derived from the analytic profile (what the engines do).
    fn shelter(planner: &mut MimosePlanner, batch: usize, seqs: &[usize]) {
        for &s in seqs {
            let profile = transformer_profile(&spec(), batch, s, 1.0);
            let input = InputDesc { batch, seqlen: s };
            let dec = planner.begin_iteration(&input, &profile);
            assert!(matches!(dec.mode, IterationMode::Sheltered(_)));
            let obs: Vec<Observation> = profile
                .layers
                .iter()
                .map(|l| Observation {
                    layer: l.id,
                    input_size: input.size() as f64,
                    act_bytes: l.act_bytes,
                    fwd_ms: l.fwd_flops as f64 / 1e9,
                    self_checkpointed: false,
                    relative_checkpointed: false,
                })
                .collect();
            planner.end_iteration(&input, &obs, 1.0);
        }
    }

    fn sheltered_seqs(n: usize) -> Vec<usize> {
        let mut rng = Rng::new(5);
        (0..n).map(|_| rng.range_u(40, 330)).collect()
    }

    #[test]
    fn sheltered_then_responsive_lifecycle() {
        let mut p = MimosePlanner::new(6 * GIB, 14, MimoseConfig::default());
        shelter(&mut p, 32, &sheltered_seqs(10));
        assert!(p.collector().is_frozen());
        // next iteration is responsive
        let profile = transformer_profile(&spec(), 32, 200, 1.0);
        let dec = p.begin_iteration(&InputDesc { batch: 32, seqlen: 200 }, &profile);
        assert!(matches!(dec.mode, IterationMode::Planned(_)));
        assert!(p.estimator().is_trained());
    }

    #[test]
    fn estimator_accuracy_after_ten_iters() {
        // Table 4: thousandth-level error on the quadratic memory curve.
        let mut p = MimosePlanner::new(6 * GIB, 14, MimoseConfig::default());
        shelter(&mut p, 32, &sheltered_seqs(10));
        let profile = transformer_profile(&spec(), 32, 200, 1.0);
        let _ = p.begin_iteration(&InputDesc { batch: 32, seqlen: 200 }, &profile);
        for l in &profile.layers {
            if l.act_bytes == 0 {
                continue;
            }
            let pred = p.estimator().predict_bytes(l.id, (32 * 200) as f64);
            let rel = (pred - l.act_bytes as f64).abs() / l.act_bytes as f64;
            assert!(rel < 5e-3, "layer {} rel {rel}", l.name);
        }
    }

    #[test]
    fn repeated_input_hits_cache() {
        let mut p = MimosePlanner::new(5 * GIB, 14, MimoseConfig::default());
        shelter(&mut p, 32, &sheltered_seqs(10));
        let profile = transformer_profile(&spec(), 32, 250, 1.0);
        let input = InputDesc { batch: 32, seqlen: 250 };
        let d1 = p.begin_iteration(&input, &profile);
        assert!(!d1.cache_hit);
        let d2 = p.begin_iteration(&input, &profile);
        assert!(d2.cache_hit);
        assert_eq!(p.plans_generated, 1);
        // a size in the same quantisation cell also hits
        let d3 = p.begin_iteration(&InputDesc { batch: 32, seqlen: 249 }, &profile);
        assert!(d3.cache_hit);
    }

    #[test]
    fn small_inputs_get_empty_plans_large_get_checkpointing() {
        // §6.4: below the budget no checkpointing; above, plans appear.
        let mut p = MimosePlanner::new(6 * GIB, 14, MimoseConfig::default());
        shelter(&mut p, 32, &sheltered_seqs(10));
        let small_prof = transformer_profile(&spec(), 32, 48, 1.0);
        let dec = p.begin_iteration(&InputDesc { batch: 32, seqlen: 48 }, &small_prof);
        match dec.mode {
            IterationMode::Planned(plan) => assert!(plan.is_empty(), "small input needs no plan"),
            _ => panic!(),
        }
        let big_prof = transformer_profile(&spec(), 32, 320, 1.0);
        let dec = p.begin_iteration(&InputDesc { batch: 32, seqlen: 320 }, &big_prof);
        match dec.mode {
            IterationMode::Planned(plan) => {
                assert!(!plan.is_empty(), "large input must checkpoint under 6 GB")
            }
            _ => panic!(),
        }
    }

    #[test]
    fn planned_memory_respects_budget() {
        let mut p = MimosePlanner::new(5 * GIB, 14, MimoseConfig::default());
        shelter(&mut p, 32, &sheltered_seqs(10));
        for seq in [100, 180, 260, 330] {
            let profile = transformer_profile(&spec(), 32, seq, 1.0);
            let dec = p.begin_iteration(&InputDesc { batch: 32, seqlen: seq }, &profile);
            if let IterationMode::Planned(plan) = dec.mode {
                let kept = profile.planned_act_bytes(&plan.ids());
                let usable = usable_activation_budget(5 * GIB, &profile, GIB / 2);
                assert!(
                    kept <= usable + usable / 50, // 2% estimator slack
                    "seq {seq}: kept {kept} > usable {usable}"
                );
            } else {
                panic!("expected planned mode");
            }
        }
    }

    #[test]
    fn planning_is_submillisecond() {
        // The paper's headline implementation claim (§4.1, Table 2).
        let mut p = MimosePlanner::new(5 * GIB, 14, MimoseConfig::default());
        shelter(&mut p, 32, &sheltered_seqs(10));
        let profile = transformer_profile(&spec(), 32, 300, 1.0);
        // warm: train once
        let _ = p.begin_iteration(&InputDesc { batch: 32, seqlen: 300 }, &profile);
        let dec = p.begin_iteration(&InputDesc { batch: 32, seqlen: 311 }, &profile);
        assert!(dec.planning_ms < 1.0, "planning took {} ms", dec.planning_ms);
    }
}
