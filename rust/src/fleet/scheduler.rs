//! The fleet scheduler: a *dynamic* set of tenant training jobs — each its
//! own [`Coordinator`]-driven [`SimEngine`] — advanced by a discrete-event
//! core against one broker-shared memory budget.
//!
//! Simulated time is a min-heap of events ([`super::events::EventQueue`]):
//! scripted `Arrive`/`Depart` instants, per-job `IterationComplete`s, and
//! broker claw-back `Rebind`s. Each job runs on its own clock — an
//! iteration starts the instant its job becomes *due* (arrival, or the
//! completion of its previous iteration) and lasts one tick under
//! [`Pacing::Lockstep`] or its simulated iteration time under
//! [`Pacing::Profiled`]. Per cohort (all events at one instant):
//! 1. departures retire first (budget reclaimed via `BudgetBroker::depart`,
//!    O(log n)), arrivals join, completions mark jobs due or retire them
//!    at their configured step count;
//! 2. each due job draws its pending mini-batch and reports a
//!    [`JobDemand`] (stable id, priority weight, conservative floor,
//!    estimator-predicted peak if trained);
//! 3. the [`BudgetBroker`] refills *incrementally*
//!    ([`BudgetBroker::update`]): only the due jobs are re-filled, non-due
//!    tenants keep their in-force budgets unless their slack must be
//!    clawed back to fit the due floors — those tightenings are applied as
//!    same-instant `Rebind` events and the tightened Coordinators replan —
//!    never OOM. When every tracked tenant is due (a lock-step cohort) the
//!    fill is bit-identical to the full [`BudgetBroker::allocate`];
//! 4. each rebound due job gets [`SimEngine::set_budget`] and runs its
//!    iteration; per-job ledger peaks are summed into the cohort's
//!    `aggregate_peak`, and the fleet-wide `alloc_total` ledger stays
//!    ≤ the global budget, always.
//!
//! [`Pacing::Rounds`] keeps the legacy interleaved round loop
//! ([`FleetScheduler::run`] dispatches) as the differential reference:
//! a static, equally-paced fleet through the event core produces the same
//! per-job allocations and iteration counts as the round loop.
//!
//! With `shared_cache` on, identical-architecture tenants exchange plans
//! through a [`crate::scheduler::SharedPlanCache`] keyed by (model
//! signature, input size, budget). The cache *retains* entries across
//! departures: a job re-arriving with the same model signature hits plans
//! contributed before its departure. Reshelters compose safely: a
//! Coordinator purges its own contributions from the shared cache when a
//! reshelter invalidates the estimator they were built from — and only its
//! own, never another tenant's.
//!
//! The event core also models *chaos*: spot-style preemption notices
//! (`Preempt` starts a notice→drain→force-stop state machine — the job
//! stops planning new iterations, parks gracefully when its in-flight
//! iteration completes within the drain window, or is force-stopped by
//! `DrainExpire`), warm re-admission (`Resume` rejoins a parked job with
//! its estimator and shared-cache entries intact, so previously seen
//! shapes replan without re-collection), and device-wide `BudgetShock`s
//! (the broker tightens every tenant to the new global via
//! [`BudgetBroker::shock`], force-stopping lowest-weight victims first
//! when even the live floors no longer fit). These kinds require the
//! event core — [`Pacing::Rounds`] rejects them at construction.
//!
//! Arriving jobs (and the whole event timeline) are validated at
//! construction: every engine is built eagerly, and the worst-case floor
//! sum over each interval of the timeline must fit the global budget, so
//! `run()` cannot hit an infeasible tenancy mid-flight. Preempted names
//! are conservatively treated as live to the horizon (a resume can push
//! their completion past `arrived + steps`), so the floor walk stays a
//! sound over-approximation; budget shocks instead re-validate at
//! runtime, force-stopping victims when a post-shock fill cannot fit.

use super::broker::{split_global, weighted_jain, BudgetBroker, DeviceBudget, JobDemand};
use super::events::{EventKind, EventQueue};
use super::metrics::{BrokerDecision, FleetReport, JobSummary};
use crate::config::{
    ExperimentConfig, FleetConfig, FleetEvent, JobSpec, Pacing, Placement, PlannerKind, Task,
};
use crate::coordinator::{Coordinator, Phase, PlanRequest};
use crate::data::InputStream;
use crate::engine::sim::{input_for_batch, ShapeMemos, SimEngine};
use crate::metrics::RunReport;
use crate::obs;
use crate::scheduler::{
    model_signature, shared_plan_cache, SharedCacheHandle, SharedPlanCache,
};
use crate::util::threadpool::{available_parallelism, ThreadPool};
use crate::util::timer::Timer;
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// Entries the per-job floor memo holds before evicting.
const FLOOR_MEMO_CAP: usize = 4096;

/// Bounded memo for conservative reservations keyed by input shape.
///
/// On overflow it evicts a *fraction* of the entries (every 4th key)
/// instead of flushing wholesale: a `clear()` stampedes profile rebuilds
/// for 2-D shape streams that legitimately visit more than the cap's worth
/// of distinct (src, tgt) shapes.
struct FloorMemo {
    map: BTreeMap<(usize, usize), u64>,
    cap: usize,
}

impl FloorMemo {
    fn new(cap: usize) -> Self {
        FloorMemo { map: BTreeMap::new(), cap: cap.max(4) }
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.map.len()
    }

    fn get_or_insert_with<F: FnOnce() -> u64>(&mut self, shape: (usize, usize), f: F) -> u64 {
        if let Some(&v) = self.map.get(&shape) {
            return v;
        }
        if self.map.len() >= self.cap {
            let victims: Vec<(usize, usize)> = self.map.keys().copied().step_by(4).collect();
            for k in victims {
                self.map.remove(&k);
            }
        }
        let v = f();
        self.map.insert(shape, v);
        v
    }
}

/// One tenant: engine + its own input stream + the budget in force.
pub struct FleetJob {
    /// Stable fleet-assigned id; broker state and input-stream seeding key
    /// off this, never off the job's position in the live vector.
    id: u64,
    pub name: String,
    task: Task,
    /// Priority/SLA weight in the broker's water-fill.
    weight: f64,
    /// Round the job joined the fleet (0 for initial tenants).
    arrived_round: usize,
    /// Iterations after which the job completes and departs (0 = never).
    steps_limit: usize,
    /// Device the job runs on — placement sets it (initial tenants at
    /// construction, scripted arrivals at their Arrive instant) and a
    /// migration rewrites it. Always 0 on a single-device fleet.
    device: usize,
    /// Budget-independent model signature: (architecture, effective batch,
    /// activation factor). Scopes the shared plan cache AND the retired-
    /// engine memo pool — two same-task tenants with different batch
    /// overrides are different models and must never exchange either.
    signature: u64,
    /// Effective mini-batch (the spec's override, or the task default).
    batch: usize,
    /// Worst-case conservative floor, frozen by the construction-time
    /// validation walk; placement and the per-device load ledger use it.
    worst: u64,
    engine: SimEngine,
    stream: InputStream,
    /// Input shape drawn for the upcoming round (demand and step must
    /// agree); (primary, secondary) with secondary 0 for 1-D tasks.
    pending: Option<(usize, usize)>,
    budget: u64,
    pub report: RunReport,
    /// Conservative reservation memo per input shape — collated shapes
    /// repeat heavily (the plan-cache premise) and the broker consults
    /// floors every iteration. Profiles come from the engine's own cache.
    floor_memo: FloorMemo,
}

impl FleetJob {
    fn new(
        spec: &JobSpec,
        id: u64,
        arrived_round: usize,
        fleet: &FleetConfig,
        budget: u64,
    ) -> Result<Self, String> {
        let task = spec.task;
        let batch = spec.batch();
        let mut cfg = ExperimentConfig::new(task, PlannerKind::Mimose, 1.0);
        cfg.batch = spec.batch;
        cfg.budget_bytes = budget;
        cfg.seed = fleet.seed + id;
        cfg.max_iters = fleet.steps;
        cfg.mimose = fleet.mimose.clone();
        cfg.coordinator = fleet.coordinator.clone();
        let seed = cfg.seed;
        let engine = SimEngine::new(cfg)
            .map_err(|e| format!("job {id} ({}): {e}", task.name()))?;
        let name = spec
            .name
            .clone()
            .unwrap_or_else(|| format!("{}#{id}", task.name()));
        let signature = model_signature(&task.model(), batch, task.act_factor());
        Ok(FleetJob {
            id,
            name,
            task,
            weight: spec.weight,
            arrived_round,
            steps_limit: spec.steps,
            device: 0,
            signature,
            batch,
            worst: 0,
            engine,
            stream: InputStream::with_batch(task, batch, seed),
            pending: None,
            budget,
            report: RunReport::new("mimose-fleet", budget),
            floor_memo: FloorMemo::new(FLOOR_MEMO_CAP),
        })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn task(&self) -> Task {
        self.task
    }

    pub fn weight(&self) -> f64 {
        self.weight
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Device the job currently runs on (0 on a single-device fleet).
    pub fn device(&self) -> usize {
        self.device
    }

    /// Budget-independent model signature (task architecture, effective
    /// batch, activation factor).
    pub fn signature(&self) -> u64 {
        self.signature
    }

    pub fn coordinator(&self) -> Option<&Coordinator> {
        self.engine.coordinator()
    }

    /// Memoised conservative reservation for an input shape (profiles come
    /// from the engine's per-shape cache, so each is built at most once).
    /// Bounded like the engine's shape memos: a 2-D (src, tgt) stream draws
    /// from a cross product, so past 4096 distinct shapes the memo evicts a
    /// fraction of its entries (see [`FloorMemo`]).
    fn floor_for(&mut self, shape: (usize, usize), reserve: u64) -> u64 {
        let engine = &mut self.engine;
        self.floor_memo.get_or_insert_with(shape, || {
            let profile = engine.profile_for_shape(shape);
            Coordinator::conservative_reservation(&profile, reserve)
        })
    }

    /// Draw the next mini-batch and report this round's memory picture.
    fn draw_demand(&mut self, configured_floor: u64, reserve: u64) -> JobDemand {
        let shape = self.stream.next_shape();
        self.pending = Some(shape);
        let floor = self.floor_for(shape, reserve).max(configured_floor);
        let profile = self.engine.profile_for_shape(shape);
        let input = input_for_batch(self.task, self.batch, shape);
        let predicted = self
            .engine
            .coordinator()
            .and_then(|c| c.predicted_demand_bytes(&input, &profile));
        JobDemand { id: self.id, weight: self.weight, floor, predicted }
    }

    /// Worst-case floor (max collated input on both axes): the tenancy
    /// must fit these. Caches the result on the job — placement and the
    /// per-device load ledger read it without recomputing.
    fn worst_floor(&mut self, configured_floor: u64, reserve: u64) -> u64 {
        let w = self.floor_for(self.task.max_shape(), reserve).max(configured_floor);
        self.worst = w;
        w
    }

    fn rebind(&mut self, budget: u64) {
        if budget != self.budget {
            self.engine.set_budget(budget);
            self.budget = budget;
        }
    }

    /// Run the round's iteration (the shape the demand was drawn for).
    fn step(&mut self) -> crate::metrics::IterationMetrics {
        let shape = self.pending.take().expect("draw_demand before step");
        self.engine.run_iteration_shape(shape)
    }

    /// True once the job has run its configured iteration count.
    fn completed(&self) -> bool {
        self.steps_limit > 0 && self.report.iters.len() >= self.steps_limit
    }

    /// Roll the job up for the final report. `departed_round` is the first
    /// round the job no longer ran (None = alive when the fleet ended).
    fn summary(&self, departed_round: Option<usize>) -> JobSummary {
        let stats = self.engine.coordinator().map(|c| c.stats());
        JobSummary {
            id: self.id,
            name: self.name.clone(),
            weight: self.weight,
            device: self.device,
            arrived_round: self.arrived_round,
            departed_round,
            steps: self.report.iters.len(),
            total_ms: self.report.total_ms(),
            peak_bytes: self.report.peak_bytes(),
            oom_failures: self.report.oom_failures(),
            cache_hit_rate: self.report.cache_hit_rate(),
            shared_hits: stats.as_ref().map(|s| s.shared_hits).unwrap_or(0),
            budget_changes: stats.as_ref().map(|s| s.budget_changes).unwrap_or(0),
            final_budget: self.budget,
            throughput_iters_per_s: self.report.throughput_iters_per_s(),
            sheltered_iters: self.report.phase_count(Phase::Sheltered),
            refits: stats.as_ref().map(|s| s.refits).unwrap_or(0),
        }
    }
}

/// An arriving job, fully constructed and validated up front, waiting for
/// its round.
struct PendingArrival {
    at_round: usize,
    job: FleetJob,
}

/// Drives a dynamic job set through discrete-event (or legacy round-loop)
/// time under one shared budget.
pub struct FleetScheduler {
    cfg: FleetConfig,
    /// Live jobs in arrival order (initial jobs first, ids ascending).
    jobs: Vec<FleetJob>,
    /// Pre-built arrivals, sorted by round (FIFO within a round).
    pending: Vec<PendingArrival>,
    /// Scripted departures, sorted by round.
    departures: Vec<(usize, String)>,
    /// Summaries of jobs that departed or completed, in departure order.
    finished: Vec<JobSummary>,
    /// One [`BudgetBroker`] per device under the global ledger; a
    /// single-device fleet passes the global through exactly.
    arbiter: DeviceBudget,
    /// Per-device shared plan caches (all `Some` or all `None`): plans move
    /// between devices only through migration adoption and the save-time
    /// merge, so one device's reshelter purges never touch another's cache.
    shared: Vec<Option<SharedCacheHandle>>,
    /// Σ worst-case floors of the jobs assigned per device — the placement
    /// load ledger (updated at place, retire, park, and migrate).
    loads: Vec<u64>,
    /// Placement decisions taken (initial tenants + scripted arrivals).
    placements: u64,
    /// Placements that landed on a device whose cache held the signature.
    placement_warm_hits: u64,
    /// Jobs migrated off a pressured device.
    migrations: u64,
    /// Σ iterations charged as migration cost.
    migration_lost_iters: u64,
    /// Static per-job share for the non-arbitrated baseline, frozen at
    /// construction as `global / max_concurrent` over the whole scripted
    /// timeline — the live count changing mid-run must NOT silently rebind
    /// every tenant (each rebind flushes plan caches).
    frozen_share: u64,
    /// Scripted preemption notices: (round, job name, drain rounds).
    preempts: Vec<(usize, String, usize)>,
    /// Scripted warm re-admissions of parked jobs: (round, job name).
    resumes: Vec<(usize, String)>,
    /// Scripted global-budget shocks: (round, new global bytes).
    shocks: Vec<(usize, u64)>,
    /// Preemption notices delivered (drain windows opened).
    preemptions: u64,
    /// Budget shocks applied.
    shocks_fired: u64,
    /// Jobs stopped mid-iteration: expired drains plus shock/fill victims.
    forced_stops: u64,
    /// Shape memos recycled from retired engines, one donor set per model
    /// signature (task, effective batch, activation factor — the same
    /// scoping as the shared plan cache) — a later same-signature arrival
    /// adopts them and skips rebuilding profiles for every shape the donor
    /// already saw (engine pooling). Keyed by signature, NOT task: two
    /// same-task tenants with different batch overrides have different
    /// profiles and must never exchange memos.
    memo_pool: HashMap<u64, ShapeMemos>,
    /// True when the shared cache was warm-loaded from `mimose.cache_path`:
    /// every Coordinator runs in warm-start mode and re-admitted tenants
    /// replan from the persisted plans with zero sheltered iterations.
    warm_loaded: bool,
}

impl FleetScheduler {
    /// Highest number of concurrently-live tenants over the scripted
    /// timeline, computed from specs alone (no engines): names are
    /// derivable (`spec.name` or `<task>#<id>` with ids in arrival order),
    /// removals are scripted departs plus `steps` completions. The walk is
    /// deliberately lenient — invalid timelines are rejected by the full
    /// validation pass that follows.
    fn max_concurrent(cfg: &FleetConfig) -> usize {
        let name_of = |spec: &JobSpec, id: usize| {
            spec.name.clone().unwrap_or_else(|| format!("{}#{id}", spec.task.name()))
        };
        // a preempted name may be resumed, pushing its completion past
        // `arrived + steps`: treat it as live to the horizon (a sound
        // over-approximation — parked jobs hold no budget, so the true
        // concurrency is never higher than this walk's)
        let preempted: BTreeSet<&str> = cfg
            .events
            .iter()
            .filter_map(|e| match e {
                FleetEvent::Preempt { job, .. } => Some(job.as_str()),
                _ => None,
            })
            .collect();
        let mut live: BTreeSet<String> = BTreeSet::new();
        let mut removals: Vec<(usize, String)> = Vec::new();
        let mut arrivals: Vec<(usize, String)> = Vec::new();
        for (i, spec) in cfg.jobs.iter().enumerate() {
            let name = name_of(spec, i);
            if spec.steps > 0 && !preempted.contains(name.as_str()) {
                removals.push((spec.steps, name.clone()));
            }
            live.insert(name);
        }
        let mut events = cfg.events.clone();
        events.sort_by_key(|e| (e.at_round(), matches!(e, FleetEvent::Arrive { .. })));
        let mut next_id = cfg.jobs.len();
        for ev in &events {
            match ev {
                FleetEvent::Depart { job, at_round } => {
                    removals.push((*at_round, job.clone()));
                }
                FleetEvent::Arrive { spec, at_round } => {
                    let name = name_of(spec, next_id);
                    next_id += 1;
                    if spec.steps > 0 && !preempted.contains(name.as_str()) {
                        removals.push((*at_round + spec.steps, name.clone()));
                    }
                    arrivals.push((*at_round, name));
                }
                // chaos kinds never RAISE concurrency: a preempt parks (live
                // count can only drop until the resume), and a shock only
                // moves budgets
                FleetEvent::Preempt { .. }
                | FleetEvent::Resume { .. }
                | FleetEvent::Shock { .. } => {}
            }
        }
        let mut ops: Vec<(usize, u8, &str)> = removals
            .iter()
            .map(|(r, name)| (*r, 0u8, name.as_str()))
            .chain(arrivals.iter().map(|(r, name)| (*r, 1u8, name.as_str())))
            .collect();
        ops.sort_by_key(|&(r, rank, _)| (r, rank));
        let mut max_live = live.len();
        for (_, rank, name) in ops {
            if rank == 0 {
                live.remove(name);
            } else {
                live.insert(name.to_string());
                max_live = max_live.max(live.len());
            }
        }
        max_live.max(1)
    }

    pub fn new(cfg: FleetConfig) -> Result<Self, String> {
        let n = cfg.jobs.len();
        if n == 0 {
            return Err("fleet needs at least one job at round 0".into());
        }
        // the TOML loader enforces these too; programmatic and CLI
        // construction must not slip past them
        if cfg.devices == 0 {
            return Err("fleet.devices must be at least 1".into());
        }
        if cfg.devices > 1 {
            if !cfg.arbitrated {
                return Err("fleet.devices > 1 requires arbitrated brokers".into());
            }
            if cfg.pacing == Pacing::Rounds {
                return Err(
                    "fleet.devices > 1 requires event pacing (lockstep/profiled)".into()
                );
            }
        }
        for spec in &cfg.jobs {
            spec.validate()?;
        }
        let equal = cfg.global_budget_bytes / n as u64;
        let frozen_share = cfg.global_budget_bytes / Self::max_concurrent(&cfg) as u64;
        // non-arbitrated tenants bind their frozen share once, at
        // construction — arbitrated ones start from the initial equal split
        // and are rebound by the broker's first fill
        let construction_budget = if cfg.arbitrated { equal } else { frozen_share };
        let mut jobs = Vec::with_capacity(n);
        for (idx, spec) in cfg.jobs.iter().enumerate() {
            jobs.push(FleetJob::new(spec, idx as u64, 0, &cfg, construction_budget)?);
        }

        // ---- phase A: build every arriving engine eagerly and collect the
        //      whole timeline — scripted departures plus the *deterministic*
        //      departures implied by per-job `steps` completion ----
        let mut events = cfg.events.clone();
        // within a round departures apply before arrivals, so a same-round
        // swap frees its floor room first
        events.sort_by_key(|e| (e.at_round(), matches!(e, FleetEvent::Arrive { .. })));
        // names under a preemption notice anywhere in the timeline: their
        // `steps` completion round is no longer deterministic (a resume
        // shifts it later), so the floor walk keeps them live to the
        // horizon — see the module docs
        let preempted: BTreeSet<String> = events
            .iter()
            .filter_map(|e| match e {
                FleetEvent::Preempt { job, .. } => Some(job.clone()),
                _ => None,
            })
            .collect();
        if events.iter().any(|e| e.is_chaos()) && cfg.pacing == Pacing::Rounds {
            return Err(
                "preempt/resume/shock events need the event core: set pacing to \
                 'lockstep' or 'profiled', not 'rounds'"
                    .into(),
            );
        }
        let mut pending: Vec<PendingArrival> = Vec::new();
        let mut departures: Vec<(usize, String)> = Vec::new();
        let mut preempts: Vec<(usize, String, usize)> = Vec::new();
        let mut resumes: Vec<(usize, String)> = Vec::new();
        let mut shocks: Vec<(usize, u64)> = Vec::new();
        // validation timeline: rounds at which a name stops/starts holding
        // worst-case floor room (removals = scripted departs + `steps`
        // completions; arrivals carry their worst-case floor)
        let mut removals: Vec<(usize, String)> = Vec::new();
        let mut arrivals: Vec<(usize, String, u64)> = Vec::new();
        let mut next_id = n as u64;
        for ev in &events {
            match ev {
                FleetEvent::Depart { job, at_round } => {
                    if *at_round >= cfg.steps {
                        return Err(format!(
                            "depart event at round {at_round} can never fire: the fleet runs {} rounds",
                            cfg.steps
                        ));
                    }
                    departures.push((*at_round, job.clone()));
                    removals.push((*at_round, job.clone()));
                }
                FleetEvent::Arrive { spec, at_round } => {
                    spec.validate()?;
                    if *at_round >= cfg.steps {
                        return Err(format!(
                            "arrive event at round {at_round} can never join: the fleet runs {} rounds",
                            cfg.steps
                        ));
                    }
                    let mut job = FleetJob::new(spec, next_id, *at_round, &cfg, construction_budget)?;
                    next_id += 1;
                    let w = job.worst_floor(cfg.floor_bytes, cfg.mimose.reserve_bytes);
                    arrivals.push((*at_round, job.name.clone(), w));
                    if spec.steps > 0 && !preempted.contains(job.name.as_str()) {
                        removals.push((*at_round + spec.steps, job.name.clone()));
                    }
                    pending.push(PendingArrival { at_round: *at_round, job });
                }
                FleetEvent::Preempt { job, at_round, drain_rounds } => {
                    if *at_round >= cfg.steps {
                        return Err(format!(
                            "preempt event at round {at_round} can never fire: the fleet runs {} rounds",
                            cfg.steps
                        ));
                    }
                    preempts.push((*at_round, job.clone(), *drain_rounds));
                }
                FleetEvent::Resume { job, at_round } => {
                    if *at_round >= cfg.steps {
                        return Err(format!(
                            "resume event at round {at_round} can never fire: the fleet runs {} rounds",
                            cfg.steps
                        ));
                    }
                    resumes.push((*at_round, job.clone()));
                }
                FleetEvent::Shock { at_round, global_budget_bytes } => {
                    if *at_round >= cfg.steps {
                        return Err(format!(
                            "shock event at round {at_round} can never fire: the fleet runs {} rounds",
                            cfg.steps
                        ));
                    }
                    if !cfg.arbitrated {
                        return Err(
                            "budget shocks need broker arbitration: the frozen equal \
                             split cannot be renegotiated mid-run"
                                .into(),
                        );
                    }
                    shocks.push((*at_round, *global_budget_bytes));
                }
            }
        }
        // preempt/resume notices must target a name the timeline can ever
        // produce — a typo'd name would otherwise be a silent no-op forever
        let known: BTreeSet<&str> = jobs
            .iter()
            .map(|j| j.name.as_str())
            .chain(pending.iter().map(|p| p.job.name.as_str()))
            .collect();
        for (round, name) in preempts
            .iter()
            .map(|(r, n, _)| (*r, n.as_str()))
            .chain(resumes.iter().map(|(r, n)| (*r, n.as_str())))
        {
            if !known.contains(name) {
                return Err(format!(
                    "preempt/resume event at round {round} names '{name}', which no \
                     initial job or scripted arrival ever uses"
                ));
            }
        }

        // ---- phase B: walk the timeline and validate every interval's
        //      worst-case floor sum (when arbitrated; names either way) ----
        // simulated live set: name -> worst-case floor
        let mut sim_live: BTreeMap<String, u64> = BTreeMap::new();
        let mut worst_sum: u64 = 0;
        for job in &mut jobs {
            let w = job.worst_floor(cfg.floor_bytes, cfg.mimose.reserve_bytes);
            if sim_live.insert(job.name.clone(), w).is_some() {
                return Err(format!("duplicate job name '{}'", job.name));
            }
            worst_sum += w;
            if job.steps_limit > 0 && !preempted.contains(job.name.as_str()) {
                removals.push((job.steps_limit, job.name.clone()));
            }
        }
        if cfg.arbitrated && worst_sum > cfg.global_budget_bytes {
            return Err(format!(
                "infeasible tenancy: worst-case floors {} exceed the global budget {}",
                worst_sum, cfg.global_budget_bytes
            ));
        }
        // merge: removals (rank 0) free their floor room before same-round
        // arrivals (rank 1) claim theirs
        let mut ops: Vec<(usize, u8, &str, u64)> = removals
            .iter()
            .map(|(r, name)| (*r, 0u8, name.as_str(), 0u64))
            .chain(arrivals.iter().map(|(r, name, w)| (*r, 1u8, name.as_str(), *w)))
            .collect();
        ops.sort_by_key(|&(r, rank, _, _)| (r, rank));
        // names that have been live at some point up to the current op —
        // distinguishes a tolerable redundant depart (the job already left
        // or completed) from a depart scheduled before its job ever arrives
        let mut ever_live: Vec<String> = sim_live.keys().cloned().collect();
        for (round, rank, name, w) in ops {
            if rank == 0 {
                // a scripted departure may race the job's own completion or
                // an earlier depart (either way it is already gone) —
                // tolerated, like at runtime; but a depart firing before
                // its job has ever arrived would silently never happen
                match sim_live.remove(name) {
                    Some(freed) => worst_sum -= freed,
                    None => {
                        if !ever_live.iter().any(|n| n.as_str() == name) {
                            return Err(format!(
                                "depart event at round {round} names '{name}', which never \
                                 arrives before then (unknown job or arrival scheduled later)"
                            ));
                        }
                    }
                }
            } else {
                ever_live.push(name.to_string());
                if sim_live.insert(name.to_string(), w).is_some() {
                    return Err(format!(
                        "arrival at round {round} reuses live job name '{name}'"
                    ));
                }
                worst_sum += w;
                if cfg.arbitrated && worst_sum > cfg.global_budget_bytes {
                    return Err(format!(
                        "infeasible tenancy from round {round}: worst-case floors {} exceed the global budget {}",
                        worst_sum, cfg.global_budget_bytes
                    ));
                }
            }
        }

        // cross-job plan reuse (reshelters purge their own stale entries —
        // see Coordinator::begin_iteration). One cache PER DEVICE: plans
        // cross devices only through migration adoption and the save-time
        // merge. Entries contributed before a signature's departure are
        // retained for its re-arrival.
        let mut warm_loaded = false;
        let shared: Vec<Option<SharedCacheHandle>> = if cfg.shared_cache {
            (0..cfg.devices)
                .map(|_| {
                    let handle = shared_plan_cache(cfg.cache_capacity);
                    // persistent warm start: a prior run's plans, scoped by
                    // model signature in every entry, so a restarted fleet
                    // re-admits its tenants without re-sheltering. A
                    // missing, corrupt, or stale-format file degrades to a
                    // cold cache, never an error.
                    if !cfg.mimose.cache_path.is_empty() {
                        let (loaded, cold_reason) = SharedPlanCache::load_from_path(
                            &cfg.mimose.cache_path,
                            cfg.cache_capacity,
                        );
                        if cold_reason.is_none() && !loaded.is_empty() {
                            warm_loaded = true;
                            *handle.borrow_mut() = loaded;
                        }
                    }
                    Some(handle)
                })
                .collect()
        } else {
            vec![None; cfg.devices]
        };
        let arbiter = DeviceBudget::new(
            cfg.global_budget_bytes,
            cfg.devices,
            cfg.grid_bytes,
            cfg.demand_smoothing,
        );
        // place the initial tenants (scripted arrivals place at their
        // Arrive instant, against the loads in force then); warm placement
        // probes the per-device caches, which a cache_path warm start may
        // already have populated
        let device_globals: Vec<u64> =
            (0..cfg.devices).map(|d| arbiter.device_global(d)).collect();
        let mut loads = vec![0u64; cfg.devices];
        let mut placements = 0u64;
        let mut placement_warm_hits = 0u64;
        for job in jobs.iter_mut() {
            let (d, warm) = Self::place_device(
                cfg.placement,
                &loads,
                &device_globals,
                &shared,
                job.signature,
                job.worst,
            );
            job.device = d;
            loads[d] += job.worst;
            placements += 1;
            placement_warm_hits += warm as u64;
        }
        // attach every tenant to its device's cache; pending arrivals
        // attach provisionally to device 0 and re-attach at their Arrive
        for job in jobs.iter_mut().chain(pending.iter_mut().map(|p| &mut p.job)) {
            if let Some(handle) = shared[job.device].as_ref() {
                if let Some(c) = job.engine.coordinator_mut() {
                    c.set_shared_cache(handle.clone(), job.signature);
                    if warm_loaded {
                        c.set_warm_start(true);
                    }
                }
            }
        }
        Ok(FleetScheduler {
            cfg,
            jobs,
            pending,
            departures,
            finished: Vec::new(),
            arbiter,
            shared,
            loads,
            placements,
            placement_warm_hits,
            migrations: 0,
            migration_lost_iters: 0,
            frozen_share,
            preempts,
            resumes,
            shocks,
            preemptions: 0,
            shocks_fired: 0,
            forced_stops: 0,
            memo_pool: HashMap::new(),
            warm_loaded,
        })
    }

    /// True when the shared cache was warm-loaded from `mimose.cache_path`
    /// at construction (every Coordinator runs in warm-start mode).
    pub fn warm_loaded(&self) -> bool {
        self.warm_loaded
    }

    /// Persist the shared plan cache for a later fleet's warm start
    /// ([`SharedPlanCache::save_to_path`]). Before serialising, every live
    /// tenant backfills a plan for each shape it saw
    /// ([`SimEngine::export_plans`]) — keys first seen while sheltered never
    /// got an organic insert, and a restarted fleet would re-shelter exactly
    /// those without the backfill. Ok-no-op when the fleet runs without a
    /// shared cache.
    pub fn save_cache(&mut self, path: &str) -> std::io::Result<()> {
        let Some(h0) = self.shared.first().and_then(|h| h.clone()) else {
            return Ok(());
        };
        for job in &mut self.jobs {
            job.engine.export_plans();
        }
        // merge the secondary devices' caches into device 0's before
        // persisting: a warm restart splits the merged file back out to
        // every device, so no device's contributions are lost
        for h in self.shared.iter().skip(1).flatten() {
            let donor = h.borrow();
            h0.borrow_mut().absorb(&donor);
        }
        h0.borrow().save_to_path(path)
    }

    /// Bank a retiring job's shape memos for a later arrival of the SAME
    /// model signature (task, effective batch, activation factor — the
    /// scoping the shared plan cache uses; profiles are functions of batch,
    /// so two same-task tenants with different overrides must never cross).
    /// Keeping the larger donor set maximises what the next arrival skips.
    fn pool_engine(memo_pool: &mut HashMap<u64, ShapeMemos>, job: &mut FleetJob) {
        let memos = job.engine.take_shape_memos();
        if memos.is_empty() {
            return;
        }
        match memo_pool.get(&job.signature) {
            Some(held) if held.len() >= memos.len() => {}
            _ => {
                memo_pool.insert(job.signature, memos);
            }
        }
    }

    /// Pick a device for a job. `FirstFit` takes the first device with
    /// worst-case floor room; `LeastLoaded` the fitting device with the
    /// smallest committed-floor fraction (ties to the lower index);
    /// `PlanCacheWarm` the least-loaded fitting device whose shared cache
    /// already holds the job's model signature, falling back to
    /// least-loaded when none does — the warm probe never strands a job.
    /// When NO device fits, the least-loaded (or, for first-fit, the first)
    /// device takes the job anyway and the runtime fill's force-stop
    /// machinery resolves the overcommit. Returns the device and whether
    /// the choice was a warm cache hit. A single-device fleet short-
    /// circuits to device 0 so every strategy is the identity there.
    fn place_device(
        placement: Placement,
        loads: &[u64],
        globals: &[u64],
        shared: &[Option<SharedCacheHandle>],
        signature: u64,
        worst: u64,
    ) -> (usize, bool) {
        let devices = loads.len();
        if devices == 1 {
            return (0, false);
        }
        // committed-floor fraction without floats:
        // load_a/glob_a < load_b/glob_b  <=>  load_a*glob_b < load_b*glob_a
        let less_loaded = |a: usize, b: usize| {
            (loads[a] as u128) * (globals[b] as u128)
                < (loads[b] as u128) * (globals[a] as u128)
        };
        let least_loaded = |cands: &[usize]| {
            let mut best = cands[0];
            for &d in &cands[1..] {
                if less_loaded(d, best) {
                    best = d;
                }
            }
            best
        };
        let fits: Vec<usize> =
            (0..devices).filter(|&d| loads[d] + worst <= globals[d]).collect();
        let all: Vec<usize> = (0..devices).collect();
        let cands: &[usize] = if fits.is_empty() { &all } else { &fits };
        match placement {
            Placement::FirstFit => (cands[0], false),
            Placement::LeastLoaded => (least_loaded(cands), false),
            Placement::PlanCacheWarm => {
                let warm: Vec<usize> = cands
                    .iter()
                    .copied()
                    .filter(|&d| {
                        shared[d]
                            .as_ref()
                            .map_or(false, |h| h.borrow().holds_signature(signature))
                    })
                    .collect();
                if warm.is_empty() {
                    (least_loaded(cands), false)
                } else {
                    (least_loaded(&warm), true)
                }
            }
        }
    }

    /// Jobs currently live, in arrival order.
    pub fn jobs(&self) -> &[FleetJob] {
        &self.jobs
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Apply the events due at the start of `round`: departures first
    /// (their budgets are reclaimed by the next fill), then arrivals.
    fn apply_events(&mut self, round: usize) {
        while let Some(pos) = self
            .departures
            .iter()
            .position(|(r, _)| *r <= round)
        {
            let (_, name) = self.departures.remove(pos);
            // a job that completed early may already be gone — that is its
            // departure having happened sooner, not an error
            if let Some(idx) = self.jobs.iter().position(|j| j.name == name) {
                let job = self.jobs.remove(idx);
                self.finished.push(job.summary(Some(round)));
            }
        }
        while let Some(pos) = self.pending.iter().position(|p| p.at_round <= round) {
            let arrival = self.pending.remove(pos);
            self.jobs.push(arrival.job);
        }
    }

    /// Retire jobs that have just run their configured iteration count:
    /// they depart at the start of the next round.
    fn retire_completed(&mut self, round: usize) {
        let mut idx = 0;
        while idx < self.jobs.len() {
            if self.jobs[idx].completed() {
                let job = self.jobs.remove(idx);
                self.finished.push(job.summary(Some(round + 1)));
            } else {
                idx += 1;
            }
        }
    }

    /// An idle decision: nobody ran at this instant. `global` is the
    /// device budget in force (post-shock runs carry the shocked value).
    /// Idle instants are recorded against device 0 — no device ran, and
    /// single-device differentials pin the round count, not the device.
    fn idle_decision(round: usize, time_ms: f64, global: u64) -> BrokerDecision {
        BrokerDecision {
            round,
            time_ms,
            job_ids: Vec::new(),
            allocations: Vec::new(),
            floors: Vec::new(),
            wants: Vec::new(),
            predicted_total: 0,
            overshoot: false,
            weighted_jain: 1.0,
            decision_ms: 0.0,
            aggregate_peak: 0,
            alloc_total: 0,
            global,
            device: 0,
        }
    }

    /// Roll the run up into the final report (live jobs are summarised as
    /// still running).
    fn finish(&self, rounds: Vec<BrokerDecision>, live: Vec<JobSummary>) -> FleetReport {
        let mut jobs: Vec<JobSummary> = self.finished.clone();
        jobs.extend(live);
        jobs.sort_by_key(|j| j.id);
        let (shared_hits, shared_entries) =
            self.shared.iter().flatten().fold((0u64, 0usize), |(hits, entries), h| {
                let c = h.borrow();
                (hits + c.stats().hits, entries + c.len())
            });
        let devices = self.cfg.devices;
        FleetReport {
            global_budget: self.cfg.global_budget_bytes,
            arbitrated: self.cfg.arbitrated,
            jobs,
            rounds,
            shared_cache_hits: shared_hits,
            shared_cache_entries: shared_entries,
            overshoots: (0..devices).map(|d| self.arbiter.broker(d).overshoots).sum(),
            preemptions: self.preemptions,
            shocks: self.shocks_fired,
            forced_stops: self.forced_stops,
            devices,
            device_globals: (0..devices).map(|d| self.arbiter.device_global(d)).collect(),
            migrations: self.migrations,
            migration_lost_iters: self.migration_lost_iters,
            placements: self.placements,
            placement_warm_hits: self.placement_warm_hits,
        }
    }

    /// Run the fleet to its horizon and report — through the discrete-event
    /// core by default, or the legacy round loop under [`Pacing::Rounds`].
    pub fn run(&mut self) -> FleetReport {
        match self.cfg.pacing {
            Pacing::Rounds => self.run_rounds(),
            Pacing::Lockstep | Pacing::Profiled => self.run_events(),
        }
    }

    /// The legacy interleaved round loop — every live job runs exactly one
    /// iteration per round. Kept as the event core's differential
    /// reference.
    fn run_rounds(&mut self) -> FleetReport {
        let mut rounds: Vec<BrokerDecision> = Vec::with_capacity(self.cfg.steps);
        for round in 0..self.cfg.steps {
            self.apply_events(round);
            let n = self.jobs.len();
            if n == 0 {
                // every tenant departed or completed: an idle round
                rounds.push(Self::idle_decision(round, round as f64, self.cfg.global_budget_bytes));
                continue;
            }

            // 1) demands for the round's pending inputs
            let demands: Vec<JobDemand> = self
                .jobs
                .iter_mut()
                .map(|j| j.draw_demand(self.cfg.floor_bytes, self.cfg.mimose.reserve_bytes))
                .collect();
            let job_ids: Vec<u64> = demands.iter().map(|d| d.id).collect();

            // 2) broker (or the static equal split it has to beat)
            let (allocations, floors, wants, predicted_total, overshoot, jain, decision_ms) =
                if self.cfg.arbitrated {
                    // the round loop is single-device (config validation
                    // pins devices = 1 to event pacing otherwise)
                    let broker: &mut BudgetBroker = self.arbiter.broker_mut(0);
                    let a = broker
                        .allocate(&demands)
                        .expect("worst-case floors validated at construction");
                    (
                        a.budgets,
                        a.floors,
                        a.wants,
                        a.predicted_total,
                        a.overshoot,
                        a.weighted_jain,
                        a.decision_ms,
                    )
                } else {
                    let t = Timer::start();
                    // the frozen share — NOT global / live-count, which
                    // would silently rebind (and flush plan caches for)
                    // every tenant whenever the live count changes
                    let share = self.frozen_share;
                    let total = demands.iter().map(|d| d.predicted.unwrap_or(d.floor)).sum();
                    let floors: Vec<u64> = demands.iter().map(|d| d.floor).collect();
                    let wants: Vec<u64> =
                        demands.iter().map(|d| d.predicted.unwrap_or(d.floor)).collect();
                    let budgets = vec![share; n];
                    let weights: Vec<f64> = demands.iter().map(|d| d.weight).collect();
                    let jain = weighted_jain(&budgets, &floors, &weights);
                    (budgets, floors, wants, total, false, jain, t.elapsed_ms())
                };
            let alloc_total = if self.cfg.arbitrated {
                self.arbiter.broker(0).alloc_total()
            } else {
                self.frozen_share * n as u64
            };
            for (job, &b) in self.jobs.iter_mut().zip(&allocations) {
                job.rebind(b);
            }

            // 3) step every live job; verify against the ledgers
            let mut aggregate_peak = 0u64;
            for job in &mut self.jobs {
                let m = job.step();
                aggregate_peak += m.peak_bytes;
                job.report.push(m);
            }
            rounds.push(BrokerDecision {
                round,
                time_ms: round as f64,
                job_ids,
                allocations,
                floors,
                wants,
                predicted_total,
                overshoot,
                weighted_jain: jain,
                decision_ms,
                aggregate_peak,
                alloc_total,
                global: self.cfg.global_budget_bytes,
                device: 0,
            });

            // 4) early exit on completion: the job's budget is reclaimed
            //    by the next round's fill
            self.retire_completed(round);
        }

        let live: Vec<JobSummary> = self.jobs.iter().map(|j| j.summary(None)).collect();
        self.finish(rounds, live)
    }

    /// The discrete-event core: jobs advance on their own clocks; per-event
    /// cost is independent of fleet size (indexed live/name maps, the
    /// broker's incremental fill).
    fn run_events(&mut self) -> FleetReport {
        let lockstep = self.cfg.pacing == Pacing::Lockstep;
        // one lockstep tick = one round, so cohorts coincide with the round
        // loop's rounds; profiled ticks are wall-clock-scaled
        let tick = if lockstep { 1.0 } else { self.cfg.tick_ms };
        let horizon = self.cfg.steps as f64 * tick;

        let mut queue = EventQueue::new();
        // live tenants keyed by id — BTreeMap iteration is id order, which
        // IS arrival order (the round loop's vec order)
        let mut live: BTreeMap<u64, FleetJob> = BTreeMap::new();
        let mut names: HashMap<String, u64> = HashMap::new();
        // initial tenants are live from t = 0 directly (NOT via Arrive
        // events: a scripted depart at round 0 ranks before arrivals and
        // must be able to find them); their first iteration is due at 0
        for job in std::mem::take(&mut self.jobs) {
            names.insert(job.name.clone(), job.id);
            queue.push(0.0, EventKind::IterationComplete { id: job.id });
            live.insert(job.id, job);
        }
        // observability: one Perfetto track per job plus a broker track for
        // fills, claw-backs, and arrive/depart instants. Strictly
        // observational — the event dynamics (and the Rounds/Lockstep
        // bit-identity differential) are untouched whether tracing is on.
        let tracing = obs::trace_enabled();
        let devices = self.cfg.devices;
        let mut broker_tid = 0usize;
        let mut dev_tids: Vec<usize> = vec![0; devices];
        let mut track_of: BTreeMap<u64, usize> = BTreeMap::new();
        if tracing {
            obs::with_tracer(|tr| {
                broker_tid = tr.track("broker");
                // multi-device fleets get one broker track per device so
                // fills and migrations group visually; a single device
                // keeps everything on the classic broker track
                dev_tids = (0..devices)
                    .map(|d| {
                        if devices == 1 {
                            broker_tid
                        } else {
                            tr.track(&format!("device{d}.broker"))
                        }
                    })
                    .collect();
                for job in live.values() {
                    track_of.insert(job.id, tr.track(&format!("job:{}", job.name)));
                }
            });
        }
        let mut waiting: BTreeMap<u64, FleetJob> = BTreeMap::new();
        for p in std::mem::take(&mut self.pending) {
            queue.push(p.at_round as f64 * tick, EventKind::Arrive { id: p.job.id });
            waiting.insert(p.job.id, p.job);
        }
        for (round, name) in std::mem::take(&mut self.departures) {
            queue.push(round as f64 * tick, EventKind::Depart { name });
        }
        // shock rounds kept for the idle-round padding below: a padded
        // round reports the global that was in force AT that round
        let shock_timeline: Vec<(usize, u64)> = self.shocks.clone();
        for (round, name, drain_rounds) in std::mem::take(&mut self.preempts) {
            queue.push(
                round as f64 * tick,
                EventKind::Preempt { name, drain_ms: drain_rounds as f64 * tick },
            );
        }
        for (round, name) in std::mem::take(&mut self.resumes) {
            queue.push(round as f64 * tick, EventKind::Resume { name });
        }
        for (round, new_global) in std::mem::take(&mut self.shocks) {
            queue.push(round as f64 * tick, EventKind::BudgetShock { new_global });
        }
        // drain/park state: the notice instant per draining id, and parked
        // (preempted) jobs with the round they parked at. A parked job
        // holds no budget (`BudgetBroker::depart` ran at park time) but
        // keeps its engine, trained estimator, and shared-cache attachment
        // for a warm resume.
        let mut draining: BTreeMap<u64, f64> = BTreeMap::new();
        let mut parked: BTreeMap<u64, (FleetJob, usize)> = BTreeMap::new();
        // the per-device budgets in force — a fleet-wide shock re-splits
        // them (one value, the global itself, on a single device)
        let mut global_now: Vec<u64> =
            (0..devices).map(|d| self.arbiter.device_global(d)).collect();
        // sustained-pressure counter per device: +1 on an overshoot fill,
        // reset on a clean one; crossing `migrate_after` migrates the
        // biggest slack holder off the device
        let mut pressure: Vec<usize> = vec![0; devices];
        // mid-move tenants: id -> iterations still to charge. The cost
        // lands at the job's next iteration boundary (see
        // IterationComplete) so a migration never tears an iteration.
        let mut migrating: BTreeMap<u64, usize> = BTreeMap::new();
        // cohort-parallel planning: plans are pure functions of
        // (profile, estimator, budget), so novel shapes across *independent*
        // tenants solve concurrently. 0 = one worker per available core;
        // 1 disables the pool (bit-identical serial planning either way —
        // the parallel path only precomputes what the serial path would).
        let plan_threads = if self.cfg.plan_threads == 0 {
            available_parallelism()
        } else {
            self.cfg.plan_threads
        };
        // spawned lazily: fleets that never see a multi-tenant cohort of
        // novel shapes pay nothing
        let mut plan_pool: Option<ThreadPool> = None;

        // one device's fill for the current instant, held until the step
        // loop has accrued its aggregate peak, then flushed as a
        // `BrokerDecision`
        struct PendingDecision {
            device: usize,
            job_ids: Vec<u64>,
            allocations: Vec<u64>,
            floors: Vec<u64>,
            wants: Vec<u64>,
            predicted_total: u64,
            overshoot: bool,
            weighted_jain: f64,
            decision_ms: f64,
            alloc_total: u64,
            aggregate_peak: u64,
        }

        // remove a live job, reclaim its device budget and load-ledger
        // room, and park it for a possible warm resume; false if not live
        fn park_job(
            arbiter: &mut DeviceBudget,
            loads: &mut [u64],
            live: &mut BTreeMap<u64, FleetJob>,
            names: &mut HashMap<String, u64>,
            parked: &mut BTreeMap<u64, (FleetJob, usize)>,
            id: u64,
            round: usize,
        ) -> bool {
            match live.remove(&id) {
                Some(job) => {
                    names.remove(&job.name);
                    arbiter.broker_mut(job.device).depart(id);
                    loads[job.device] = loads[job.device].saturating_sub(job.worst);
                    parked.insert(id, (job, round));
                    true
                }
                None => false,
            }
        }

        let mut rounds: Vec<BrokerDecision> = Vec::new();
        while let Some(cohort) = queue.pop_cohort() {
            let t = cohort[0].time;
            if t > horizon {
                break;
            }
            let round = (t / tick) as usize;
            obs::gauge_set("fleet.queue_depth", queue.len() as u64);
            let mut due: Vec<u64> = Vec::new();
            for ev in cohort {
                match ev.kind {
                    EventKind::Depart { name } => {
                        // unknown or already-gone: a redundant depart, the
                        // earlier departure (or completion) won — tolerated
                        let id = names.get(&name).copied();
                        if let Some(id) = id {
                            let mut job = live.remove(&id).expect("names tracks live jobs");
                            names.remove(&name);
                            // a depart mid-drain releases the floor exactly
                            // once: `depart` here, and the dropped notice
                            // makes the pending DrainExpire a no-op
                            draining.remove(&id);
                            migrating.remove(&id);
                            self.arbiter.broker_mut(job.device).depart(id);
                            self.loads[job.device] =
                                self.loads[job.device].saturating_sub(job.worst);
                            Self::pool_engine(&mut self.memo_pool, &mut job);
                            self.finished.push(job.summary(Some(round)));
                            if tracing {
                                obs::with_tracer(|tr| {
                                    let label = format!("depart:{name}");
                                    tr.instant_at(broker_tid, &label, "broker", t, &[]);
                                });
                            }
                        } else if let Some(id) = parked
                            .iter()
                            .find(|(_, (j, _))| j.name == name)
                            .map(|(&id, _)| id)
                        {
                            // departing while parked: the budget was already
                            // reclaimed at park time — just retire the job
                            let (mut job, _) = parked.remove(&id).expect("just found");
                            Self::pool_engine(&mut self.memo_pool, &mut job);
                            self.finished.push(job.summary(Some(round)));
                        }
                    }
                    EventKind::Arrive { id } => {
                        if let Some(mut job) = waiting.remove(&id) {
                            // engine pooling: adopt a retired same-SIGNATURE
                            // donor's shape memos so first sight of each
                            // shape the donor saw skips profile construction
                            // (signature, not task: a batch-overridden
                            // tenant must never inherit another batch's
                            // profiles)
                            if let Some(memos) = self.memo_pool.remove(&job.signature) {
                                job.engine.adopt_shape_memos(memos);
                            }
                            // placement: pick the device against the loads
                            // in force NOW, and re-attach the tenant to its
                            // device's shared cache (construction attached
                            // it provisionally to device 0)
                            let (d, warm) = Self::place_device(
                                self.cfg.placement,
                                &self.loads,
                                &global_now,
                                &self.shared,
                                job.signature,
                                job.worst,
                            );
                            job.device = d;
                            self.loads[d] += job.worst;
                            self.placements += 1;
                            self.placement_warm_hits += warm as u64;
                            if let Some(handle) = self.shared[d].as_ref() {
                                if let Some(c) = job.engine.coordinator_mut() {
                                    c.set_shared_cache(handle.clone(), job.signature);
                                }
                            }
                            let jname = job.name.clone();
                            names.insert(job.name.clone(), id);
                            live.insert(id, job);
                            due.push(id);
                            if tracing {
                                obs::with_tracer(|tr| {
                                    track_of.insert(id, tr.track(&format!("job:{jname}")));
                                    let label = format!("arrive:{jname}");
                                    tr.instant_at(broker_tid, &label, "broker", t, &[]);
                                });
                            }
                        }
                    }
                    EventKind::IterationComplete { id } => {
                        // a departed job's stale completion finds nothing
                        match live.get(&id).map(|j| j.completed()) {
                            Some(true) => {
                                // configured step count reached: retire now
                                let mut job = live.remove(&id).expect("checked live");
                                names.remove(&job.name);
                                draining.remove(&id);
                                migrating.remove(&id);
                                self.arbiter.broker_mut(job.device).depart(id);
                                self.loads[job.device] =
                                    self.loads[job.device].saturating_sub(job.worst);
                                Self::pool_engine(&mut self.memo_pool, &mut job);
                                self.finished.push(job.summary(Some(round)));
                            }
                            Some(false) => {
                                if let Some(cost) = migrating.remove(&id) {
                                    // the migration charges its cost here,
                                    // at the iteration boundary: the job
                                    // sits out exactly `cost` iterations'
                                    // worth of ticks before becoming due
                                    // again on its new device
                                    queue.push(
                                        t + cost as f64 * tick,
                                        EventKind::IterationComplete { id },
                                    );
                                } else if let Some(notice) = draining.remove(&id) {
                                    // the in-flight iteration finished
                                    // inside the drain window: park
                                    // gracefully, release the floor
                                    park_job(
                                        &mut self.arbiter,
                                        &mut self.loads,
                                        &mut live,
                                        &mut names,
                                        &mut parked,
                                        id,
                                        round,
                                    );
                                    obs::observe_ms("fleet.drain_ms", t - notice);
                                    if tracing {
                                        let tid = track_of.get(&id).copied();
                                        obs::with_tracer(|tr| {
                                            let tid = tid.unwrap_or(broker_tid);
                                            tr.span_at(tid, "drain", "job", notice, t - notice, &[]);
                                        });
                                    }
                                } else {
                                    due.push(id);
                                }
                            }
                            None => {}
                        }
                    }
                    EventKind::Rebind { id, budget } => {
                        // broker claw-back from a previous cohort at this
                        // instant: the tightened Coordinator replans
                        if let Some(job) = live.get_mut(&id) {
                            job.rebind(budget);
                            if tracing {
                                obs::with_tracer(|tr| {
                                    tr.instant_at(
                                        broker_tid,
                                        "rebind",
                                        "broker",
                                        t,
                                        &[("id", id as f64), ("budget", budget as f64)],
                                    );
                                });
                            }
                        }
                    }
                    EventKind::Preempt { name, drain_ms } => {
                        // a notice for a parked or departed name is stale;
                        // a second notice mid-drain does not reset the clock
                        if let Some(&id) = names.get(&name) {
                            if !draining.contains_key(&id) {
                                draining.insert(id, t);
                                self.preemptions += 1;
                                obs::inc("fleet.preemptions");
                                queue.push(t + drain_ms, EventKind::DrainExpire { id });
                                if tracing {
                                    obs::with_tracer(|tr| {
                                        let label = format!("preempt:{name}");
                                        tr.instant_at(
                                            broker_tid,
                                            &label,
                                            "broker",
                                            t,
                                            &[("drain_ms", drain_ms)],
                                        );
                                    });
                                }
                            }
                        }
                    }
                    EventKind::Resume { name } => {
                        // warm re-admission: the parked engine rejoins with
                        // its estimator and shared-cache attachment intact,
                        // so previously seen shapes replan with zero new
                        // sheltered iterations and no refit. The broker
                        // re-registers it at the next fill, like a fresh
                        // arrival. A name that is not parked is stale.
                        let pid = parked
                            .iter()
                            .find(|(_, (j, _))| j.name == name)
                            .map(|(&id, _)| id);
                        if let Some(id) = pid {
                            let (job, _) = parked.remove(&id).expect("just found");
                            // a resume rejoins the device it parked on —
                            // its estimator and cache attachment are that
                            // device's; reclaim its load-ledger room and
                            // drop any move that was interrupted by the park
                            migrating.remove(&id);
                            self.loads[job.device] += job.worst;
                            names.insert(job.name.clone(), id);
                            live.insert(id, job);
                            due.push(id);
                            if tracing {
                                obs::with_tracer(|tr| {
                                    let label = format!("resume:{name}");
                                    tr.instant_at(broker_tid, &label, "broker", t, &[]);
                                });
                            }
                        }
                    }
                    EventKind::BudgetShock { new_global } => {
                        self.shocks_fired += 1;
                        obs::inc("fleet.shocks");
                        // every device's new slice must cover its live
                        // floors before the arbiter can transition:
                        // force-stop the lowest-weight victims ON THE
                        // OFFENDING DEVICE (ties to the larger id — the
                        // later arrival) until they fit
                        let slices = split_global(new_global, devices);
                        for d in 0..devices {
                            while self.arbiter.broker(d).floor_sum_live() > slices[d] {
                                let victim = live
                                    .values()
                                    .filter(|j| {
                                        j.device == d
                                            && self
                                                .arbiter
                                                .broker(d)
                                                .allocation_of(j.id)
                                                .is_some()
                                    })
                                    .min_by(|a, b| {
                                        a.weight.total_cmp(&b.weight).then(b.id.cmp(&a.id))
                                    })
                                    .map(|j| j.id);
                                match victim {
                                    Some(id) => {
                                        draining.remove(&id);
                                        migrating.remove(&id);
                                        park_job(
                                            &mut self.arbiter,
                                            &mut self.loads,
                                            &mut live,
                                            &mut names,
                                            &mut parked,
                                            id,
                                            round,
                                        );
                                        self.forced_stops += 1;
                                        obs::inc("fleet.forced_stops");
                                    }
                                    None => break,
                                }
                            }
                        }
                        let rebinds = self
                            .arbiter
                            .shock(new_global)
                            .expect("victims force-stopped until the floors fit");
                        // tightenings land as same-instant rebind events
                        // (the follow-up cohort), like claw-backs from fills
                        for (_, id, budget) in rebinds {
                            queue.push(t, EventKind::Rebind { id, budget });
                        }
                        global_now =
                            (0..devices).map(|d| self.arbiter.device_global(d)).collect();
                        obs::gauge_set("fleet.global_budget", new_global);
                        if tracing {
                            obs::with_tracer(|tr| {
                                tr.instant_at(
                                    broker_tid,
                                    "shock",
                                    "broker",
                                    t,
                                    &[("new_global", new_global as f64)],
                                );
                            });
                        }
                    }
                    EventKind::DrainExpire { id } => {
                        // the drain window closed with the iteration still
                        // in flight: force-stop. Parked, departed, and
                        // completed ids already dropped their notice.
                        if let Some(notice) = draining.remove(&id) {
                            if park_job(
                                &mut self.arbiter,
                                &mut self.loads,
                                &mut live,
                                &mut names,
                                &mut parked,
                                id,
                                round,
                            ) {
                                self.forced_stops += 1;
                                obs::inc("fleet.forced_stops");
                                obs::observe_ms("fleet.drain_ms", t - notice);
                                if tracing {
                                    let tid = track_of.get(&id).copied();
                                    obs::with_tracer(|tr| {
                                        let tid = tid.unwrap_or(broker_tid);
                                        tr.span_at(
                                            tid,
                                            "drain:forced",
                                            "job",
                                            notice,
                                            t - notice,
                                            &[],
                                        );
                                    });
                                }
                            }
                        }
                    }
                    EventKind::Migrate { id, to } => {
                        // depart the pressured device and warm-arrive on the
                        // target: the engine, estimator, and memos move with
                        // the job (no refit, no re-sheltering) and the job
                        // adopts the target's shared cache. A stale notice
                        // (departed/parked/draining id, or a shock that beat
                        // it to this instant) is a no-op.
                        if let Some(job) = live.get_mut(&id) {
                            if job.device != to && !draining.contains_key(&id) {
                                let from = job.device;
                                self.arbiter.broker_mut(from).depart(id);
                                self.loads[from] =
                                    self.loads[from].saturating_sub(job.worst);
                                self.loads[to] += job.worst;
                                job.device = to;
                                if let Some(handle) = self.shared[to].as_ref() {
                                    if let Some(c) = job.engine.coordinator_mut() {
                                        c.set_shared_cache(handle.clone(), job.signature);
                                    }
                                }
                                // the cost (lost iterations) is charged at
                                // the job's next iteration boundary — see
                                // IterationComplete
                                let cost = self.cfg.migration_cost_iters;
                                migrating.insert(id, cost);
                                self.migrations += 1;
                                self.migration_lost_iters += cost as u64;
                                obs::inc("fleet.migrations");
                                if tracing {
                                    let jname = job.name.clone();
                                    obs::with_tracer(|tr| {
                                        let label = format!("migrate:{jname}");
                                        tr.instant_at(
                                            dev_tids[to],
                                            &label,
                                            "broker",
                                            t,
                                            &[
                                                ("from", from as f64),
                                                ("to", to as f64),
                                                ("cost_iters", cost as f64),
                                            ],
                                        );
                                    });
                                }
                            }
                        }
                    }
                }
            }
            if t >= horizon {
                continue; // the horizon instant processes retirements only
            }
            due.sort_unstable();
            due.dedup();
            // a shock (or a zero-notice drain expiry) later in the cohort
            // may have force-stopped a job after its completion marked it
            // due; and a preempt after a same-instant completion puts a due
            // job under notice — its iteration finished at this very
            // instant, so it parks gracefully instead of starting a new
            // one. Draining jobs never receive new slack.
            due.retain(|&id| {
                if !live.contains_key(&id) {
                    return false;
                }
                if let Some(notice) = draining.remove(&id) {
                    park_job(
                        &mut self.arbiter,
                        &mut self.loads,
                        &mut live,
                        &mut names,
                        &mut parked,
                        id,
                        round,
                    );
                    obs::observe_ms("fleet.drain_ms", t - notice);
                    return false;
                }
                true
            });
            if due.is_empty() {
                continue; // departure/rebind-only instant
            }

            // 1) demands and fills, device by device. Each device's broker
            //    sees only its own tenants; `due` is sorted, so every
            //    per-device group keeps ascending id order, and on a single
            //    device the one group IS the old cohort — bit-identical.
            let mut due_by_dev: Vec<Vec<u64>> = vec![Vec::new(); devices];
            for &id in &due {
                let d = live.get(&id).expect("due jobs are live").device;
                due_by_dev[d].push(id);
            }
            let mut fills: Vec<PendingDecision> = Vec::new();
            // ids that survived their device's fill, with their budgets;
            // drained back into one ascending-id cohort below
            let mut rebound: Vec<(u64, u64)> = Vec::new();
            for (d, dev_due) in due_by_dev.iter_mut().enumerate() {
                let mut dev_due = std::mem::take(dev_due);
                if dev_due.is_empty() {
                    continue;
                }
                let mut demands: Vec<JobDemand> = dev_due
                    .iter()
                    .map(|id| {
                        live.get_mut(id)
                            .expect("due jobs are live")
                            .draw_demand(self.cfg.floor_bytes, self.cfg.mimose.reserve_bytes)
                    })
                    .collect();
                let decision = if self.cfg.arbitrated {
                    // a shock can invalidate the construction-time floor
                    // walk for later arrivals and resumes: when the fill
                    // cannot cover the due floors, force-stop the lowest-
                    // weight victims on this device until it can. Shock-
                    // free timelines take the Ok path on the first try —
                    // bit-identical to the pre-chaos behavior.
                    let fill = loop {
                        match self.arbiter.broker_mut(d).update(&demands) {
                            Ok(f) => break Some(f),
                            Err(_) => {
                                let victim = live
                                    .values()
                                    .filter(|j| {
                                        j.device == d
                                            && (self
                                                .arbiter
                                                .broker(d)
                                                .allocation_of(j.id)
                                                .is_some()
                                                || demands.iter().any(|dm| dm.id == j.id))
                                    })
                                    .min_by(|a, b| {
                                        a.weight.total_cmp(&b.weight).then(b.id.cmp(&a.id))
                                    })
                                    .map(|j| j.id);
                                let vid = match victim {
                                    Some(vid) => vid,
                                    None => break None,
                                };
                                draining.remove(&vid);
                                park_job(
                                    &mut self.arbiter,
                                    &mut self.loads,
                                    &mut live,
                                    &mut names,
                                    &mut parked,
                                    vid,
                                    round,
                                );
                                self.forced_stops += 1;
                                obs::inc("fleet.forced_stops");
                                dev_due.retain(|&x| x != vid);
                                demands.retain(|dm| dm.id != vid);
                                if demands.is_empty() {
                                    break None;
                                }
                            }
                        }
                    };
                    // an un-fillable device skips its fill this instant;
                    // the other devices still run theirs
                    let Some(fill) = fill else { continue };
                    // claw-backs land as same-instant rebind events (the
                    // follow-up cohort), after this cohort's iterations
                    for &(id, budget) in &fill.rebinds {
                        queue.push(t, EventKind::Rebind { id, budget });
                    }
                    let a = fill.alloc;
                    // sustained-pressure bookkeeping: an overshoot fill
                    // bumps the device's counter, a clean one resets it
                    if a.overshoot {
                        pressure[d] += 1;
                    } else {
                        pressure[d] = 0;
                    }
                    PendingDecision {
                        device: d,
                        job_ids: dev_due,
                        allocations: a.budgets,
                        floors: a.floors,
                        wants: a.wants,
                        predicted_total: a.predicted_total,
                        overshoot: a.overshoot,
                        weighted_jain: a.weighted_jain,
                        decision_ms: a.decision_ms,
                        alloc_total: self.arbiter.broker(d).alloc_total(),
                        aggregate_peak: 0,
                    }
                } else {
                    // the frozen equal split never arbitrates, and config
                    // validation pins non-arbitrated fleets to one device
                    let timer = Timer::start();
                    let share = self.frozen_share;
                    let total =
                        demands.iter().map(|dm| dm.predicted.unwrap_or(dm.floor)).sum();
                    let floors: Vec<u64> = demands.iter().map(|dm| dm.floor).collect();
                    let wants: Vec<u64> =
                        demands.iter().map(|dm| dm.predicted.unwrap_or(dm.floor)).collect();
                    let budgets = vec![share; demands.len()];
                    let weights: Vec<f64> = demands.iter().map(|dm| dm.weight).collect();
                    let jain = weighted_jain(&budgets, &floors, &weights);
                    PendingDecision {
                        device: d,
                        job_ids: dev_due,
                        allocations: budgets,
                        floors,
                        wants,
                        predicted_total: total,
                        overshoot: false,
                        weighted_jain: jain,
                        decision_ms: timer.elapsed_ms(),
                        alloc_total: self.frozen_share * live.len() as u64,
                        aggregate_peak: 0,
                    }
                };
                if tracing {
                    let n_due = decision.job_ids.len() as f64;
                    let decision_ms = decision.decision_ms;
                    obs::with_tracer(|tr| {
                        tr.instant_at(
                            dev_tids[d],
                            "fill",
                            "broker",
                            t,
                            &[("n_due", n_due), ("decision_ms", decision_ms)],
                        );
                    });
                }
                rebound.extend(
                    decision.job_ids.iter().copied().zip(decision.allocations.iter().copied()),
                );
                fills.push(decision);
            }
            if fills.is_empty() {
                continue; // every device's fill came up empty
            }

            // 2) sustained pressure migrates the biggest slack holder off
            //    the device: queued as a same-instant Migrate event (ranked
            //    after everything else in the follow-up cohort), so this
            //    cohort's iterations still run where they were filled.
            if devices > 1 && self.cfg.migrate_after > 0 {
                for d in 0..devices {
                    if pressure[d] < self.cfg.migrate_after {
                        continue;
                    }
                    let victim = self
                        .arbiter
                        .broker(d)
                        .claw_candidates()
                        .into_iter()
                        .map(|(id, _slack)| id)
                        .find(|id| {
                            live.get(id).map_or(false, |j| j.device == d)
                                && !draining.contains_key(id)
                                && !migrating.contains_key(id)
                        });
                    if let Some(vid) = victim {
                        let worst = live.get(&vid).expect("victim is live").worst;
                        // least-loaded other device with headroom for the
                        // victim's worst-case floor; ties to the lower index
                        let mut target: Option<usize> = None;
                        for e in (0..devices).filter(|&e| e != d) {
                            if self.loads[e] + worst > self.arbiter.device_global(e) {
                                continue;
                            }
                            let better = match target {
                                None => true,
                                Some(best) => {
                                    (self.loads[e] as u128)
                                        * (self.arbiter.device_global(best) as u128)
                                        < (self.loads[best] as u128)
                                            * (self.arbiter.device_global(e) as u128)
                                }
                            };
                            if better {
                                target = Some(e);
                            }
                        }
                        if let Some(to) = target {
                            queue.push(t, EventKind::Migrate { id: vid, to });
                        }
                    }
                    // one migration attempt per pressure episode, even when
                    // no candidate or target exists — avoids re-firing
                    // every instant while the device stays hot
                    pressure[d] = 0;
                }
            }

            // 3) rebind and run the surviving iterations as one cohort, in
            //    ascending id order across devices — with one device this
            //    is exactly the old due order; each iteration schedules its
            //    own completion one duration ahead
            rebound.sort_unstable_by_key(|&(id, _)| id);
            for &(id, b) in &rebound {
                live.get_mut(&id).expect("due jobs are live").rebind(b);
            }

            // 3a) cohort-parallel planning: after the rebinds (budgets are
            //     final for this instant), extract the planning problem of
            //     every due tenant whose iteration would run Algorithm 1
            //     (novel quantised key, estimator trained, no cache hit —
            //     see Coordinator::peek_plan_request), solve them
            //     concurrently, and stash the results back in job-id order.
            //     Each stashed plan is bit-identical to what the serial miss
            //     path would compute, and a stash invalidated between here
            //     and the step (shared-cache race, reshelter) is silently
            //     dropped — so Rounds/Lockstep differentials and the chaos
            //     ledger invariants are untouched.
            if plan_threads > 1 && rebound.len() > 1 {
                let mut requests: Vec<(u64, PlanRequest)> = Vec::new();
                for &(id, _) in &rebound {
                    let job = live.get_mut(&id).expect("due jobs are live");
                    let shape = job.pending.expect("draw_demand precedes planning");
                    let profile = job.engine.profile_for_shape(shape);
                    let input = input_for_batch(job.task, job.batch, shape);
                    if let Some(req) = job
                        .engine
                        .coordinator()
                        .and_then(|c| c.peek_plan_request(&input, &profile))
                    {
                        requests.push((id, req));
                    }
                }
                if requests.len() > 1 {
                    let timer = Timer::start();
                    let pool =
                        plan_pool.get_or_insert_with(|| ThreadPool::new(plan_threads));
                    let solved = pool
                        .map(requests, |(id, req)| (id, req.plan_key, req.epoch, req.solve()));
                    // merge deterministically: `rebound` is sorted, `map`
                    // preserves order, so stashes land in job-id order
                    for (id, key, epoch, plan) in solved {
                        if let Some(c) = live
                            .get_mut(&id)
                            .and_then(|j| j.engine.coordinator_mut())
                        {
                            c.stash_plan(key, plan, epoch);
                        }
                    }
                    obs::inc("planner.parallel_cohort");
                    obs::observe_ms("planner.plan_ms", timer.elapsed_ms());
                }
            }

            // each step's peak accrues to its device's pending decision
            let mut fill_idx: Vec<Option<usize>> = vec![None; devices];
            for (i, f) in fills.iter().enumerate() {
                fill_idx[f.device] = Some(i);
            }
            for &(id, budget) in &rebound {
                let job = live.get_mut(&id).expect("due jobs are live");
                if tracing {
                    // stage spans emitted inside the engine land on this
                    // job's track, clocked to the event core's `t`
                    let tid = track_of.get(&id).copied();
                    obs::with_tracer(|tr| {
                        let tid =
                            tid.unwrap_or_else(|| tr.track(&format!("job:{}", job.name)));
                        tr.set_current(tid);
                        tr.set_clock_ms(tid, t);
                    });
                }
                let m = job.step();
                if let Some(i) = fill_idx[job.device] {
                    fills[i].aggregate_peak += m.peak_bytes;
                }
                let peak = m.peak_bytes as f64;
                let duration = if lockstep {
                    tick
                } else {
                    // a zero-cost iteration must still advance time, or the
                    // queue would loop at one instant forever
                    m.total_ms().max(1e-3 * tick)
                };
                if tracing {
                    let tid = track_of.get(&id).copied();
                    obs::with_tracer(|tr| {
                        let tid =
                            tid.unwrap_or_else(|| tr.track(&format!("job:{}", job.name)));
                        tr.span_at(
                            tid,
                            "iter",
                            "job",
                            t,
                            duration,
                            &[("budget", budget as f64), ("peak_bytes", peak)],
                        );
                    });
                }
                queue.push(t + duration, EventKind::IterationComplete { id });
                job.report.push(m);
            }
            // one decision per device that filled this instant — a single
            // device emits exactly the one decision the old core did
            for f in fills {
                rounds.push(BrokerDecision {
                    round,
                    time_ms: t,
                    job_ids: f.job_ids,
                    allocations: f.allocations,
                    floors: f.floors,
                    wants: f.wants,
                    predicted_total: f.predicted_total,
                    overshoot: f.overshoot,
                    weighted_jain: f.weighted_jain,
                    decision_ms: f.decision_ms,
                    aggregate_peak: f.aggregate_peak,
                    alloc_total: f.alloc_total,
                    global: global_now[f.device],
                    device: f.device,
                });
            }
        }

        if lockstep {
            // the round loop records every round, active or idle; pad the
            // instants no cohort covered so differentials line up 1:1
            let mut have = vec![false; self.cfg.steps];
            for d in &rounds {
                have[d.round] = true;
            }
            for (round, seen) in have.into_iter().enumerate() {
                if !seen {
                    // the fleet global that was in force AT the padded
                    // round; idle decisions report device 0's slice of it
                    // (the whole global on a single device)
                    let fleet_global = shock_timeline
                        .iter()
                        .filter(|(r, _)| *r <= round)
                        .last()
                        .map(|(_, g)| *g)
                        .unwrap_or(self.cfg.global_budget_bytes);
                    let global = split_global(fleet_global, devices)[0];
                    rounds.push(Self::idle_decision(round, round as f64, global));
                }
            }
            rounds.sort_by_key(|d| d.round);
        }

        // jobs still parked at the horizon never resumed: they retire with
        // the round they parked at
        for (job, park_round) in parked.into_values() {
            self.finished.push(job.summary(Some(park_round)));
        }
        let live_summaries: Vec<JobSummary> = live.values().map(|j| j.summary(None)).collect();
        // restore the live set so `jobs()` still reflects it post-run
        self.jobs = live.into_values().collect();
        self.finish(rounds, live_summaries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::GIB;

    fn fleet_cfg(tasks: Vec<Task>, global_gb: u64, steps: usize) -> FleetConfig {
        FleetConfig {
            global_budget_bytes: global_gb * GIB,
            steps,
            jobs: JobSpec::from_tasks(&tasks),
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn two_jobs_complete_within_the_shared_budget() {
        let mut f =
            FleetScheduler::new(fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 60)).unwrap();
        let r = f.run();
        assert_eq!(r.jobs.len(), 2);
        for j in &r.jobs {
            assert_eq!(j.steps, 60, "{} incomplete", j.name);
            assert_eq!(j.oom_failures, 0, "{} OOMed", j.name);
            assert_eq!(j.arrived_round, 0);
            assert_eq!(j.departed_round, None, "{} should outlive the fleet", j.name);
        }
        assert!(r.budget_respected(), "aggregate peak {}", r.max_aggregate_peak());
        for d in &r.rounds {
            assert!(d.allocations.iter().sum::<u64>() <= 12 * GIB);
            assert_eq!(d.job_ids, vec![0, 1]);
        }
    }

    #[test]
    fn seq2seq_tenant_coexists_in_the_fleet() {
        // a two-axis (graph) workload shares the budget with a chain task:
        // shaped demand, shaped floors, shaped iterations — end to end
        let mut f =
            FleetScheduler::new(fleet_cfg(vec![Task::Seq2seq, Task::TcBert], 14, 40)).unwrap();
        let r = f.run();
        assert_eq!(r.jobs.len(), 2);
        assert_eq!(r.oom_failures(), 0);
        assert!(r.budget_respected(), "aggregate peak {}", r.max_aggregate_peak());
        let s2s = r.jobs.iter().find(|j| j.name.starts_with("Seq2seq")).unwrap();
        assert_eq!(s2s.steps, 40);
    }

    #[test]
    fn infeasible_tenancy_rejected_up_front() {
        // four QA jobs cannot fit their conservative floors into 8 GB
        let cfg = fleet_cfg(vec![Task::QaXlnet; 4], 8, 10);
        assert!(FleetScheduler::new(cfg).is_err());
    }

    #[test]
    fn infeasible_arrival_rejected_up_front() {
        // the initial pair fits 20 GB, but the scheduled arrivals push the
        // timeline to ten QA tenants — four already cannot fit 8 GB of
        // floors (see infeasible_tenancy_rejected_up_front), so ten cannot
        // fit 20: construction must reject the whole scenario
        let mut cfg = fleet_cfg(vec![Task::QaXlnet, Task::QaXlnet], 20, 40);
        cfg.events = (0..8)
            .map(|i| FleetEvent::Arrive {
                spec: JobSpec::new(Task::QaXlnet),
                at_round: 10 + i,
            })
            .collect();
        assert!(FleetScheduler::new(cfg).is_err());
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(FleetScheduler::new(fleet_cfg(vec![], 8, 10)).is_err());
    }

    #[test]
    fn depart_event_must_name_a_known_job() {
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.events = vec![FleetEvent::Depart { job: "nope".into(), at_round: 5 }];
        assert!(FleetScheduler::new(cfg).is_err());
    }

    #[test]
    fn redundant_departs_are_tolerated_first_one_wins() {
        // a second depart (or one racing the job's own completion) finds the
        // job already gone — a no-op, exactly like at runtime
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 20);
        cfg.events = vec![
            FleetEvent::Depart { job: "TC-Bert#0".into(), at_round: 5 },
            FleetEvent::Depart { job: "TC-Bert#0".into(), at_round: 9 },
        ];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        let j = r.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        assert_eq!(j.departed_round, Some(5), "the earlier depart wins");
        assert_eq!(j.steps, 5);
    }

    #[test]
    fn arrival_beyond_fleet_end_rejected() {
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.events = vec![FleetEvent::Arrive {
            spec: JobSpec::new(Task::McRoberta),
            at_round: 20,
        }];
        assert!(
            FleetScheduler::new(cfg).is_err(),
            "an arrival at round >= steps can never join and must not vanish silently"
        );
    }

    #[test]
    fn depart_beyond_fleet_end_rejected() {
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.events = vec![FleetEvent::Depart { job: "TC-Bert#0".into(), at_round: 20 }];
        assert!(
            FleetScheduler::new(cfg).is_err(),
            "a depart at round >= steps can never fire and must not vanish silently"
        );
    }

    #[test]
    fn depart_before_arrival_rejected() {
        // the depart would fire at round 5 as a no-op and the round-10
        // arrival would then never leave — reject the contradiction
        let mut cfg = fleet_cfg(vec![Task::TcBert], 12, 20);
        cfg.events = vec![
            FleetEvent::Depart { job: "MC-Roberta#1".into(), at_round: 5 },
            FleetEvent::Arrive { spec: JobSpec::new(Task::McRoberta), at_round: 10 },
        ];
        assert!(FleetScheduler::new(cfg).is_err());
        // ordered the other way round (arrive 5, depart 10) it is fine
        let mut cfg = fleet_cfg(vec![Task::TcBert], 12, 20);
        cfg.events = vec![
            FleetEvent::Arrive { spec: JobSpec::new(Task::McRoberta), at_round: 5 },
            FleetEvent::Depart { job: "MC-Roberta#1".into(), at_round: 10 },
        ];
        let r = FleetScheduler::new(cfg).unwrap().run();
        let j = r.jobs.iter().find(|j| j.name == "MC-Roberta#1").unwrap();
        assert_eq!((j.arrived_round, j.departed_round), (5, Some(10)));
        assert_eq!(j.steps, 5);
    }

    #[test]
    fn completion_frees_floor_room_for_later_arrival() {
        // the validation timeline models `steps` completion: the MC tenant
        // is deterministically gone by round 5, so the round-10 arrival
        // joins a fleet of the same shape that was feasible at round 0
        let mut cfg = fleet_cfg(
            vec![Task::McRoberta, Task::QaXlnet, Task::QaBert, Task::TcBert],
            16,
            30,
        );
        cfg.jobs[0].steps = 5;
        cfg.events = vec![FleetEvent::Arrive {
            spec: JobSpec::new(Task::McRoberta),
            at_round: 10,
        }];
        let mut f = FleetScheduler::new(cfg).expect("completion must free the floor room");
        let r = f.run();
        assert_eq!(r.jobs.len(), 5);
        let done = r.jobs.iter().find(|j| j.id == 0).unwrap();
        assert_eq!((done.steps, done.departed_round), (5, Some(5)));
        let arrival = r.jobs.iter().find(|j| j.id == 4).unwrap();
        assert_eq!((arrival.arrived_round, arrival.steps), (10, 20));
        assert_eq!(r.oom_failures(), 0);
        assert!(r.budget_respected());
    }

    #[test]
    fn equal_split_mode_never_rebinds() {
        let cfg = FleetConfig {
            arbitrated: false,
            ..fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40)
        };
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert!(!r.arbitrated);
        for j in &r.jobs {
            assert_eq!(j.budget_changes, 0);
            assert_eq!(j.final_budget, 6 * GIB);
        }
        assert_eq!(r.overshoots, 0);
    }

    #[test]
    fn equal_split_stays_frozen_through_dynamic_timeline() {
        // the "static" baseline was silently rebinding (and flushing plan
        // caches) whenever the live count changed: the split is now frozen
        // at global / max-concurrent over the whole scripted timeline
        let mut cfg = FleetConfig {
            arbitrated: false,
            ..fleet_cfg(vec![Task::TcBert, Task::McRoberta], 18, 40)
        };
        cfg.events = vec![
            FleetEvent::Arrive { spec: JobSpec::new(Task::TcBert), at_round: 10 },
            FleetEvent::Depart { job: "MC-Roberta#1".into(), at_round: 25 },
        ];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.jobs.len(), 3);
        for j in &r.jobs {
            assert_eq!(j.budget_changes, 0, "{} rebound under a frozen split", j.name);
            assert_eq!(j.final_budget, 6 * GIB, "18 GiB / 3 max-concurrent tenants");
            assert_eq!(j.oom_failures, 0);
        }
        for d in &r.rounds {
            assert!(d.allocations.iter().sum::<u64>() <= 18 * GIB);
            assert!(d.alloc_total <= 18 * GIB, "round {}: ledger blown", d.round);
        }
        assert_eq!(r.overshoots, 0);
    }

    #[test]
    fn floor_memo_evicts_a_fraction_not_everything() {
        let mut memo = FloorMemo::new(8);
        let mut builds = 0usize;
        for i in 0..8 {
            memo.get_or_insert_with((i, 0), || {
                builds += 1;
                i as u64
            });
        }
        assert_eq!((builds, memo.len()), (8, 8));
        // the 9th distinct shape overflows: only every 4th key is evicted
        let v = memo.get_or_insert_with((8, 0), || {
            builds += 1;
            99
        });
        assert_eq!((v, builds), (99, 9));
        assert!(memo.len() <= 8, "the bound holds after overflow");
        assert!(memo.len() >= 6, "a fraction was evicted, not a wholesale flush");
        // the memo stays mostly warm when the shapes repeat — the old
        // clear() forced a rebuild of everything
        let before = builds;
        for i in 0..9 {
            memo.get_or_insert_with((i, 0), || {
                builds += 1;
                i as u64
            });
        }
        assert!(builds - before <= 4, "only evicted keys rebuild: {}", builds - before);
        assert!(memo.len() <= 8);
        // a hit returns the memoised value without invoking the builder
        assert_eq!(memo.get_or_insert_with((8, 0), || unreachable!("hit")), 99);
    }

    #[test]
    fn identical_tenants_reuse_each_others_plans() {
        let mut f =
            FleetScheduler::new(fleet_cfg(vec![Task::TcBert, Task::TcBert], 14, 80)).unwrap();
        let r = f.run();
        assert!(
            r.shared_cache_hits > 0,
            "same-architecture tenants must exchange plans"
        );
        assert!(r.jobs.iter().map(|j| j.shared_hits).sum::<u64>() > 0);
        assert!(r.shared_cache_entries > 0);
    }

    #[test]
    fn shared_cache_off_means_no_cross_hits() {
        let cfg = FleetConfig {
            shared_cache: false,
            ..fleet_cfg(vec![Task::TcBert, Task::TcBert], 14, 40)
        };
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.shared_cache_hits, 0);
        assert_eq!(r.shared_cache_entries, 0);
    }

    #[test]
    fn broker_tightens_slack_holders_on_overshoot() {
        // a tight device forces demand above the budget once estimators
        // train: overshoot rounds must appear and still never OOM
        let mut f =
            FleetScheduler::new(fleet_cfg(vec![Task::QaBert, Task::TcBert], 9, 80)).unwrap();
        let r = f.run();
        assert!(r.overshoots > 0, "9 GB must be contended");
        assert_eq!(r.oom_failures(), 0, "overshoot resolves by replanning, not OOM");
        assert!(r.budget_respected());
        let rebinds: u64 = r.jobs.iter().map(|j| j.budget_changes).sum();
        assert!(rebinds > 0, "tightening must rebind at least one tenant");
    }

    #[test]
    fn departure_reclaims_budget_and_arrival_joins_mid_run() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 20, 50);
        cfg.events = vec![
            FleetEvent::Arrive { spec: JobSpec::new(Task::TcBert), at_round: 10 },
            FleetEvent::Depart { job: "MC-Roberta#1".into(), at_round: 30 },
        ];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.jobs.len(), 3);
        let by_name = |n: &str| r.jobs.iter().find(|j| j.name == n).unwrap();
        let initial = by_name("TC-Bert#0");
        assert_eq!(initial.steps, 50);
        assert_eq!((initial.arrived_round, initial.departed_round), (0, None));
        let departed = by_name("MC-Roberta#1");
        assert_eq!(departed.steps, 30, "departed at round 30: ran rounds 0..30");
        assert_eq!(departed.departed_round, Some(30));
        let arrival = by_name("TC-Bert#2");
        assert_eq!(arrival.steps, 40, "arrived at round 10: ran rounds 10..50");
        assert_eq!((arrival.arrived_round, arrival.departed_round), (10, None));
        assert_eq!(r.oom_failures(), 0);
        assert!(r.budget_respected());
        // the departed job's id leaves the decision vector from round 30 on
        for d in &r.rounds {
            let has_departed = d.job_ids.contains(&1);
            assert_eq!(has_departed, d.round < 30, "round {}", d.round);
            let has_arrival = d.job_ids.contains(&2);
            assert_eq!(has_arrival, d.round >= 10, "round {}", d.round);
        }
    }

    #[test]
    fn completed_job_departs_on_its_own() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40);
        cfg.jobs[1].steps = 15;
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        let short = r.jobs.iter().find(|j| j.name == "MC-Roberta#1").unwrap();
        assert_eq!(short.steps, 15);
        assert_eq!(short.departed_round, Some(15), "completed after its 15th round");
        for d in &r.rounds {
            assert_eq!(d.job_ids.contains(&1), d.round < 15, "round {}", d.round);
        }
        let long = r.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        assert_eq!(long.steps, 40);
    }

    #[test]
    fn fleet_can_idle_when_everyone_departs() {
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.jobs[0].steps = 5;
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.jobs.len(), 1);
        assert_eq!(r.jobs[0].steps, 5);
        assert_eq!(r.rounds.len(), 20);
        for d in &r.rounds[5..] {
            assert!(d.job_ids.is_empty(), "round {} should be idle", d.round);
            assert_eq!(d.aggregate_peak, 0);
        }
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::TcBert], 14, 20);
        cfg.jobs[0].name = Some("same".into());
        cfg.jobs[1].name = Some("same".into());
        assert!(FleetScheduler::new(cfg).is_err());
    }

    #[test]
    fn preempted_job_parks_and_resumes_warm() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40);
        cfg.events = vec![
            FleetEvent::Preempt { job: "TC-Bert#0".into(), at_round: 20, drain_rounds: 2 },
            FleetEvent::Resume { job: "TC-Bert#0".into(), at_round: 30 },
        ];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.preemptions, 1);
        assert_eq!(
            r.forced_stops, 0,
            "lockstep iterations end on tick boundaries: the park is graceful"
        );
        let j = r.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        // parked over rounds 20..30: 20 iterations before, 10 after
        assert_eq!(j.steps, 30);
        assert_eq!(j.departed_round, None, "resumed and live at the fleet's end");
        // the warm-resume pin: the retained estimator means no refit and no
        // new sheltered (collection) iterations versus an unpreempted run
        let mut base =
            FleetScheduler::new(fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40)).unwrap();
        let rb = base.run();
        let jb = rb.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        assert_eq!(j.refits, jb.refits, "warm resume must not refit the estimator");
        assert_eq!(
            j.sheltered_iters, jb.sheltered_iters,
            "warm resume must add zero sheltered iterations"
        );
        // the parked interval shows in the decisions: id 0 absent 20..30
        for d in &r.rounds {
            let has = d.job_ids.contains(&0);
            assert_eq!(has, !(20..30).contains(&d.round), "round {}", d.round);
        }
        assert_eq!(r.oom_failures(), 0);
        assert!(r.budget_respected());
    }

    #[test]
    fn drain_expiry_force_stops_mid_iteration() {
        // profiled pacing: iterations end on simulated durations, so a
        // zero-notice preempt lands mid-iteration and the drain expires
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40);
        cfg.pacing = Pacing::Profiled;
        cfg.events = vec![FleetEvent::Preempt {
            job: "TC-Bert#0".into(),
            at_round: 20,
            drain_rounds: 0,
        }];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.forced_stops, 1, "no drain window: the job stops mid-iteration");
        let j = r.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        assert!(j.departed_round.is_some(), "never resumed: retired at its park round");
    }

    #[test]
    fn shock_tightens_mid_run_and_decisions_carry_the_new_global() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40);
        cfg.events = vec![FleetEvent::Shock { at_round: 20, global_budget_bytes: 8 * GIB }];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.shocks, 1);
        assert_eq!(r.forced_stops, 0, "8 GiB still covers both floors");
        assert_eq!(r.oom_failures(), 0, "the shock resolves by replanning, not OOM");
        for d in &r.rounds {
            let expect = if d.round < 20 { 12 * GIB } else { 8 * GIB };
            assert_eq!(d.global, expect, "round {}", d.round);
            assert!(d.alloc_total <= d.global, "round {}: ledger blown", d.round);
        }
        // both jobs survive to the horizon under the tightened budget
        for j in &r.jobs {
            assert_eq!(j.steps, 40, "{} incomplete", j.name);
        }
    }

    #[test]
    fn shock_below_the_floors_evicts_the_lowest_weight_victim() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40);
        cfg.jobs[0].weight = 4.0;
        cfg.jobs[1].weight = 1.0;
        cfg.events = vec![FleetEvent::Shock { at_round: 20, global_budget_bytes: 3 * GIB }];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.shocks, 1);
        assert!(r.forced_stops >= 1, "3 GiB cannot cover both floors");
        let victim = r.jobs.iter().find(|j| j.name == "MC-Roberta#1").unwrap();
        assert_eq!(
            victim.departed_round,
            Some(20),
            "the lowest-weight tenant is force-stopped at the shock"
        );
        assert_eq!(victim.steps, 20);
        for d in &r.rounds {
            assert!(d.alloc_total <= d.global, "round {}: ledger blown", d.round);
            assert!(!d.job_ids.contains(&1) || d.round < 20, "round {}", d.round);
        }
    }

    #[test]
    fn depart_while_parked_retires_the_job_once() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40);
        cfg.events = vec![
            FleetEvent::Preempt { job: "TC-Bert#0".into(), at_round: 10, drain_rounds: 2 },
            FleetEvent::Depart { job: "TC-Bert#0".into(), at_round: 15 },
            FleetEvent::Resume { job: "TC-Bert#0".into(), at_round: 25 },
        ];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.jobs.len(), 2, "exactly one summary per job");
        let j = r.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        assert_eq!(j.departed_round, Some(15), "the depart retires the parked job");
        assert_eq!(j.steps, 10);
        // the stale resume at 25 must NOT revive the departed job
        for d in &r.rounds {
            assert!(!d.job_ids.contains(&0) || d.round < 10, "round {}", d.round);
        }
        assert_eq!(r.preemptions, 1);
    }

    #[test]
    fn preempt_and_resume_work_under_the_frozen_equal_split() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 40);
        cfg.arbitrated = false;
        cfg.events = vec![
            FleetEvent::Preempt { job: "TC-Bert#0".into(), at_round: 10, drain_rounds: 1 },
            FleetEvent::Resume { job: "TC-Bert#0".into(), at_round: 20 },
        ];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.preemptions, 1);
        let j = r.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        assert_eq!(j.steps, 30, "parked rounds 10..20");
        assert_eq!(j.final_budget, 6 * GIB, "the frozen share survives park/resume");
    }

    #[test]
    fn resume_of_a_live_job_is_a_stale_no_op() {
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 30);
        cfg.events = vec![FleetEvent::Resume { job: "TC-Bert#0".into(), at_round: 10 }];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!((r.preemptions, r.shocks, r.forced_stops), (0, 0, 0));
        for j in &r.jobs {
            assert_eq!(j.steps, 30, "{} must be unaffected", j.name);
        }
        assert_eq!(r.oom_failures(), 0);
    }

    #[test]
    fn chaos_events_need_the_event_core_and_known_names() {
        // the legacy round loop cannot host preempt/resume/shock
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.pacing = Pacing::Rounds;
        cfg.events =
            vec![FleetEvent::Preempt { job: "TC-Bert#0".into(), at_round: 5, drain_rounds: 1 }];
        assert!(FleetScheduler::new(cfg).is_err());
        // a typo'd preempt target would be a silent no-op forever
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.events =
            vec![FleetEvent::Preempt { job: "nope".into(), at_round: 5, drain_rounds: 1 }];
        assert!(FleetScheduler::new(cfg).is_err());
        // shocks need the broker: a frozen split cannot renegotiate
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.arbitrated = false;
        cfg.events = vec![FleetEvent::Shock { at_round: 5, global_budget_bytes: 4 * GIB }];
        assert!(FleetScheduler::new(cfg).is_err());
        // chaos events at or past the horizon can never fire
        let mut cfg = fleet_cfg(vec![Task::TcBert], 8, 20);
        cfg.events = vec![FleetEvent::Resume { job: "TC-Bert#0".into(), at_round: 20 }];
        assert!(FleetScheduler::new(cfg).is_err());
    }

    #[test]
    fn preempted_name_stays_live_in_the_timeline_walk() {
        // a steps-limited job under a preempt notice may be resumed past
        // `arrived + steps`, so the concurrency/floor walks must NOT free
        // its room at the nominal completion round. Pinned through the
        // frozen equal split: with job 0's completion at round 5 counted,
        // the round-10 arrival would never overlap it (max-concurrent 1,
        // share 12 GiB); with job 0 preempted it is held live to the
        // horizon (max-concurrent 2, share 6 GiB).
        let mut cfg = fleet_cfg(vec![Task::TcBert], 12, 30);
        cfg.arbitrated = false;
        cfg.jobs[0].steps = 5;
        cfg.events = vec![
            FleetEvent::Arrive { spec: JobSpec::new(Task::TcBert), at_round: 10 },
            FleetEvent::Preempt { job: "TC-Bert#0".into(), at_round: 2, drain_rounds: 1 },
        ];
        let r = FleetScheduler::new(cfg).unwrap().run();
        let arrival = r.jobs.iter().find(|j| j.name == "TC-Bert#1").unwrap();
        assert_eq!(
            arrival.final_budget,
            6 * GIB,
            "the preempted name holds its slot to the horizon"
        );
        // the never-resumed job retires at its park round with 2 steps
        let parked = r.jobs.iter().find(|j| j.name == "TC-Bert#0").unwrap();
        assert_eq!((parked.steps, parked.departed_round), (2, Some(2)));
    }

    /// Everything deterministic a fleet run produces, for differential pins.
    fn fingerprint(r: &FleetReport) -> Vec<String> {
        let mut fp = Vec::new();
        for j in &r.jobs {
            fp.push(format!(
                "job {} steps={} peak={} oom={} sheltered={} shared={} hit={:.6} budget={}",
                j.name,
                j.steps,
                j.peak_bytes,
                j.oom_failures,
                j.sheltered_iters,
                j.shared_hits,
                j.cache_hit_rate,
                j.final_budget
            ));
        }
        for d in &r.rounds {
            fp.push(format!(
                "round {} ids={:?} alloc={:?} floors={:?} peak={} total={} global={}",
                d.round, d.job_ids, d.allocations, d.floors, d.aggregate_peak,
                d.alloc_total, d.global
            ));
        }
        fp
    }

    #[test]
    fn cohort_parallel_planning_is_bit_identical_to_serial() {
        // four tenants, all due every lockstep tick: the parallel planner
        // precomputes the novel-shape cohort on a pool, the serial run plans
        // inline — every allocation, peak, and cache statistic must agree,
        // including under shared-cache cross-tenant reuse (a wasted parallel
        // solve for a key another tenant inserts first is dropped, not used)
        let tasks = vec![Task::TcBert, Task::McRoberta, Task::TcBert, Task::McRoberta];
        let mut serial_cfg = fleet_cfg(tasks.clone(), 24, 50);
        serial_cfg.plan_threads = 1;
        let serial = FleetScheduler::new(serial_cfg).unwrap().run();
        let mut par_cfg = fleet_cfg(tasks, 24, 50);
        par_cfg.plan_threads = 8;
        let parallel = FleetScheduler::new(par_cfg).unwrap().run();
        assert_eq!(fingerprint(&serial), fingerprint(&parallel));
        assert_eq!(serial.oom_failures(), 0);
        assert!(serial.jobs.iter().any(|j| j.steps == 50));
    }

    #[test]
    fn departed_engines_donate_their_shape_memos() {
        // a retiring tenant banks its per-shape memos under its model
        // SIGNATURE; a later same-signature arrival adopts them (and the
        // run is identical either way — the memos are pure functions of
        // (model, batch, shape))
        let tc_sig = model_signature(
            &Task::TcBert.model(),
            Task::TcBert.batch(),
            Task::TcBert.act_factor(),
        );
        let mc_sig = model_signature(
            &Task::McRoberta.model(),
            Task::McRoberta.batch(),
            Task::McRoberta.act_factor(),
        );
        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 30);
        cfg.events = vec![FleetEvent::Depart { job: "TC-Bert#0".into(), at_round: 10 }];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.oom_failures(), 0);
        let banked = f.memo_pool.get(&tc_sig).expect("departed engine banks its memos");
        assert!(!banked.is_empty());
        assert!(f.memo_pool.get(&mc_sig).is_none(), "live engines keep theirs");

        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 30);
        cfg.events = vec![
            FleetEvent::Depart { job: "TC-Bert#0".into(), at_round: 10 },
            FleetEvent::Arrive { spec: JobSpec::new(Task::TcBert), at_round: 12 },
        ];
        let mut f2 = FleetScheduler::new(cfg).unwrap();
        let r2 = f2.run();
        assert_eq!(r2.oom_failures(), 0);
        assert!(
            f2.memo_pool.get(&tc_sig).is_none(),
            "the same-signature arrival drains the pool"
        );
        let arrival = r2.jobs.iter().find(|j| j.name == "TC-Bert#2").unwrap();
        assert_eq!(arrival.steps, 30 - 12);
    }

    #[test]
    fn batch_overridden_tenants_do_not_cross_adopt_memos() {
        // regression: the pool was once keyed by Task alone, so a batch-8
        // TC-Bert arrival could adopt a departed batch-32 tenant's shape
        // memos — activation profiles sized for the wrong batch. Signature
        // keys (model, batch, act-factor) fence them apart.
        let donor_sig = model_signature(
            &Task::TcBert.model(),
            Task::TcBert.batch(),
            Task::TcBert.act_factor(),
        );
        let small_sig = model_signature(&Task::TcBert.model(), 8, Task::TcBert.act_factor());
        assert_ne!(donor_sig, small_sig, "batch must scope the signature");

        let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 30);
        cfg.events = vec![
            FleetEvent::Depart { job: "TC-Bert#0".into(), at_round: 10 },
            FleetEvent::Arrive {
                spec: JobSpec { batch: Some(8), ..JobSpec::new(Task::TcBert) },
                at_round: 12,
            },
        ];
        let mut f = FleetScheduler::new(cfg).unwrap();
        let r = f.run();
        assert_eq!(r.oom_failures(), 0);
        assert!(
            f.memo_pool.get(&donor_sig).is_some(),
            "the batch-32 donor's memos stay banked — the batch-8 arrival must not drain them"
        );
        assert!(f.memo_pool.get(&small_sig).is_none());
        let arrival = r.jobs.iter().find(|j| j.name == "TC-Bert#2").unwrap();
        assert_eq!(arrival.steps, 30 - 12, "the fenced arrival still runs to the horizon");
    }

    #[test]
    fn placement_strategies_pick_the_expected_device() {
        use crate::scheduler::Plan;
        let loads = [6 * GIB, 2 * GIB, 3 * GIB];
        let globals = [8 * GIB, 8 * GIB, 8 * GIB];
        let sig = 7u64;
        let warm = shared_plan_cache(16);
        warm.borrow_mut().insert(sig, (128, 0), GIB, Plan::of([0usize]));
        let shared: Vec<Option<SharedCacheHandle>> = vec![None, None, Some(warm)];
        let place = |p: Placement, sig: u64, worst: u64| {
            FleetScheduler::place_device(p, &loads, &globals, &shared, sig, worst)
        };
        // first-fit: the lowest-index device with headroom for the worst
        // floor — device 0 fits 6 + 1 <= 8
        assert_eq!(place(Placement::FirstFit, sig, GIB), (0, false));
        // least-loaded by committed-floor fraction: device 1 at 2/8
        assert_eq!(place(Placement::LeastLoaded, sig, GIB), (1, false));
        // warm: device 2 holds the signature, so it wins despite its load
        assert_eq!(place(Placement::PlanCacheWarm, sig, GIB), (2, true));
        // a signature nobody holds falls back to least-loaded, cold
        assert_eq!(place(Placement::PlanCacheWarm, 99, GIB), (1, false));
        // nothing fits a 7 GiB worst floor: every strategy degrades to its
        // rule over ALL devices rather than parking the tenant
        assert_eq!(place(Placement::FirstFit, sig, 7 * GIB), (0, false));
        assert_eq!(place(Placement::LeastLoaded, sig, 7 * GIB), (1, false));
    }

    #[test]
    fn warm_start_restarts_with_zero_sheltered_iterations() {
        // run -> save -> restart with the persisted cache: the frozen equal
        // split keeps every budget constant across both runs and the
        // save-time backfill covers every shape run 1 ever saw, so run 2
        // (same seeds, same stream) warm-hits every iteration — zero
        // sheltered, zero refits
        let path = std::env::temp_dir()
            .join(format!("mimose-warm-test-{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let cold_cfg = || {
            let mut cfg = fleet_cfg(vec![Task::TcBert, Task::McRoberta], 12, 60);
            cfg.arbitrated = false;
            cfg
        };
        let mut f1 = FleetScheduler::new(cold_cfg()).unwrap();
        assert!(!f1.warm_loaded(), "no cache file yet: cold start");
        let r1 = f1.run();
        assert!(
            r1.jobs.iter().all(|j| j.sheltered_iters > 0),
            "the cold fleet must shelter before it can plan"
        );
        f1.save_cache(&path).unwrap();

        let mut warm_cfg = cold_cfg();
        warm_cfg.mimose.cache_path = path.clone();
        let mut f2 = FleetScheduler::new(warm_cfg).unwrap();
        assert!(f2.warm_loaded(), "the persisted cache must load warm");
        let r2 = f2.run();
        let _ = std::fs::remove_file(&path);
        assert_eq!(r2.oom_failures(), 0);
        assert!(r2.budget_respected());
        for j in &r2.jobs {
            assert_eq!(j.sheltered_iters, 0, "{} re-sheltered on warm start", j.name);
            assert_eq!(j.refits, 0, "{} retrained on warm start", j.name);
            assert_eq!(j.steps, 60);
        }

        // corrupt cache file: degrade to a cold start, never an error
        std::fs::write(&path, "{ not json").unwrap();
        let mut bad_cfg = cold_cfg();
        bad_cfg.mimose.cache_path = path.clone();
        let f3 = FleetScheduler::new(bad_cfg).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(!f3.warm_loaded(), "corrupt cache must degrade to cold");
    }
}
