"""Manual forward/backward primitives with *explicit* residual tensors.

Why manual VJPs instead of jax.grad: the Rust engine (L3) implements
checkpointing at block granularity, holding residual buffers between separate
PJRT executables. jax.vjp returns a Python closure and cannot be exported
across an executable boundary, so each primitive here returns its residuals
as plain tensors and exposes a backward that consumes them. Every backward is
validated against jax.grad in python/tests/test_layers.py.

The residual *sets* mirror what PyTorch eager keeps alive for autograd — that
correspondence is what makes the L3 memory ledger faithful to the paper.
"""

import jax
import jax.numpy as jnp

from .kernels import ref

# ---------------------------------------------------------------------------
# Linear: y = x @ W + b, x: [..., I], W: [I, O]
# ---------------------------------------------------------------------------

def linear_fwd(x, w, b):
    y = jnp.einsum("...i,io->...o", x, w) + b
    return y, (x,)


def linear_bwd(res, w, gy):
    (x,) = res
    gx = jnp.einsum("...o,io->...i", gy, w)
    gw = jnp.einsum("...i,...o->io", x, gy)
    gb = jnp.sum(gy, axis=tuple(range(gy.ndim - 1)))
    return gx, gw, gb


# ---------------------------------------------------------------------------
# LayerNorm over last axis with affine params g, b.
# ---------------------------------------------------------------------------

def layernorm_fwd(x, g, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * rstd
    y = xhat * g + b
    return y, (xhat, rstd)


def layernorm_bwd(res, g, gy):
    xhat, rstd = res
    h = xhat.shape[-1]
    gxhat = gy * g
    # Standard layernorm input-gradient:
    # gx = rstd/H * (H*gxhat - sum(gxhat) - xhat * sum(gxhat*xhat))
    sum_g = jnp.sum(gxhat, axis=-1, keepdims=True)
    sum_gx = jnp.sum(gxhat * xhat, axis=-1, keepdims=True)
    gx = (rstd / h) * (h * gxhat - sum_g - xhat * sum_gx)
    red = tuple(range(gy.ndim - 1))
    gg = jnp.sum(gy * xhat, axis=red)
    gb = jnp.sum(gy, axis=red)
    return gx, gg, gb


# ---------------------------------------------------------------------------
# GELU (tanh approximation).
# ---------------------------------------------------------------------------

def gelu_fwd(x):
    return ref.gelu(x), (x,)


def gelu_bwd(res, gy):
    (x,) = res
    return gy * ref.gelu_grad(x)


# ---------------------------------------------------------------------------
# Softmax over last axis (backward consumes the forward output p).
# ---------------------------------------------------------------------------

def softmax_bwd(p, gp):
    return p * (gp - jnp.sum(gp * p, axis=-1, keepdims=True))


# ---------------------------------------------------------------------------
# Multi-head attention (eager: materialises probs as a residual).
#   x: [B, S, H]; params W*: [H, H].
# ---------------------------------------------------------------------------

def _split_heads(x, heads):
    b, s, h = x.shape
    return x.reshape(b, s, heads, h // heads).transpose(0, 2, 1, 3)  # [B,h,S,d]


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def attention_fwd(x, wq, bq, wk, bk, wv, bv, wo, bo, heads):
    """Returns (out, residuals). Residuals: x, q, k, v, p, ctx.

    q/k/v/ctx are stored head-split ([B,h,S,d]); p is [B,h,S,S] — the
    quadratic-in-seqlen tensor the paper's estimator keys on.
    """
    q, _ = linear_fwd(x, wq, bq)
    k, _ = linear_fwd(x, wk, bk)
    v, _ = linear_fwd(x, wv, bv)
    qh, kh, vh = (_split_heads(t, heads) for t in (q, k, v))
    ctxh, p = ref.attention_with_probs(qh, kh, vh)
    ctx = _merge_heads(ctxh)
    out, _ = linear_fwd(ctx, wo, bo)
    return out, (x, qh, kh, vh, p, ctx)


def attention_bwd(res, wq, wk, wv, wo, gy):
    """Returns gx and grads for all 8 attention params (order q,k,v,o)."""
    x, qh, kh, vh, p, ctx = res
    heads, d = qh.shape[1], qh.shape[3]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    gctx, gwo, gbo = linear_bwd((ctx,), wo, gy)
    gctxh = _split_heads(gctx, heads)

    gp = jnp.einsum("bhqd,bhkd->bhqk", gctxh, vh)
    gvh = jnp.einsum("bhqk,bhqd->bhkd", p, gctxh)
    gs = softmax_bwd(p, gp) * scale
    gqh = jnp.einsum("bhqk,bhkd->bhqd", gs, kh)
    gkh = jnp.einsum("bhqk,bhqd->bhkd", gs, qh)

    gq, gk, gv = (_merge_heads(t) for t in (gqh, gkh, gvh))
    gx_q, gwq, gbq = linear_bwd((x,), wq, gq)
    gx_k, gwk, gbk = linear_bwd((x,), wk, gk)
    gx_v, gwv, gbv = linear_bwd((x,), wv, gv)
    gx = gx_q + gx_k + gx_v
    return gx, (gwq, gbq, gwk, gbk, gwv, gbv, gwo, gbo)


def attention_fwd_flash(x, wq, bq, wk, bk, wv, bv, wo, bo, heads,
                        block_q=64, block_k=64):
    """Forward-only attention through the L1 Pallas flash kernel.

    Used by the flash block variant (no residuals kept: the [S,S] tensors are
    never materialised, so activation memory is linear in seqlen).
    """
    from .kernels import flash_attention

    q, _ = linear_fwd(x, wq, bq)
    k, _ = linear_fwd(x, wk, bk)
    v, _ = linear_fwd(x, wv, bv)
    qh, kh, vh = (_split_heads(t, heads) for t in (q, k, v))
    ctxh = flash_attention(qh, kh, vh, block_q=block_q, block_k=block_k)
    out, _ = linear_fwd(_merge_heads(ctxh), wo, bo)
    return out
