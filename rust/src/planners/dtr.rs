//! DTR (Dynamic Tensor Rematerialization, Kirisame et al. [24]) reimplemented
//! as the paper's dynamic-planner baseline.
//!
//! DTR keeps no model knowledge: when an allocation OOMs it greedily evicts
//! live activations with the smallest heuristic
//! `h(t) = compute_cost / (memory * staleness)` until the request fits.
//! Because it treats every iteration independently, it re-derives the same
//! evictions for repeated input sizes — the redundant planning overhead the
//! paper measures in Fig 5 (4.40% avg, 6.06% max of iteration time).

use super::{InputDesc, IterationMode, OomResponse, PlanDecision, Planner};
use crate::coordinator::Phase;
use crate::memory::{Ledger, TensorId};
use crate::model::ModelProfile;

pub struct DtrPlanner {
    /// Modelled metadata-scan cost per candidate tensor per eviction round
    /// (µs). Real DTR walks its tensor table on every OOM; on the paper's
    /// testbed this amounts to the Fig 5 planning share. Calibrated in
    /// benches/fig5_dtr_overhead.rs.
    pub scan_cost_us_per_tensor: f64,
    /// Dispatch-tracking overhead (µs per traced op): DTR wraps every
    /// framework op to record cost/staleness metadata, paying this even
    /// with no memory pressure (DTR paper reports >1.0x unbounded overhead;
    /// Mimose Fig 13 shows DTR above Baseline at every budget).
    pub track_cost_us_per_op: f64,
    /// Traced ops per model layer (BERT encoder ~60 primitive ops).
    pub ops_per_layer: f64,
    /// Total modelled planning time spent in eviction scans (ms).
    pub planning_ms_total: f64,
    /// Number of eviction rounds performed.
    pub evictions: u64,
}

impl DtrPlanner {
    pub fn new() -> Self {
        DtrPlanner {
            scan_cost_us_per_tensor: 8.0,
            track_cost_us_per_op: 15.0,
            ops_per_layer: 60.0,
            planning_ms_total: 0.0,
            evictions: 0,
        }
    }

    /// The DTR heuristic: smaller h = better eviction victim.
    fn heuristic(cost: f64, bytes: u64, staleness: u64) -> f64 {
        cost / ((bytes as f64).max(1.0) * (staleness as f64).max(1.0))
    }
}

impl Default for DtrPlanner {
    fn default() -> Self {
        Self::new()
    }
}

impl Planner for DtrPlanner {
    fn name(&self) -> &'static str {
        "dtr"
    }

    fn begin_iteration(&mut self, _input: &InputDesc, profile: &ModelProfile) -> PlanDecision {
        // no a-priori plan: run reactively; pay per-op dispatch tracking
        let tracking_ms =
            profile.layers().len() as f64 * self.ops_per_layer * self.track_cost_us_per_op / 1e3;
        self.planning_ms_total += tracking_ms;
        PlanDecision {
            mode: IterationMode::Reactive,
            planning_ms: tracking_ms,
            cache_hit: false,
            phase: Phase::Reactive,
        }
    }

    fn on_oom(&mut self, ledger: &Ledger, needed: u64) -> OomResponse {
        let now = ledger.clock();
        let mut cands: Vec<(f64, TensorId, u64)> = ledger
            .evictable()
            .into_iter()
            .map(|(id, t)| {
                (
                    Self::heuristic(t.compute_cost, t.bytes, now - t.last_access.min(now)),
                    id,
                    t.bytes,
                )
            })
            .collect();
        if cands.is_empty() {
            return OomResponse::Fail;
        }
        // each eviction round rescans the table: cost ∝ candidates scanned
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut victims = Vec::new();
        let mut freed = 0u64;
        let mut scanned = 0usize;
        for (_, id, bytes) in &cands {
            scanned += cands.len(); // greedy DTR rescans per eviction
            victims.push(*id);
            freed += bytes;
            if freed >= needed {
                break;
            }
        }
        if freed < needed {
            return OomResponse::Fail;
        }
        let planning_ms = scanned as f64 * self.scan_cost_us_per_tensor / 1e3;
        self.planning_ms_total += planning_ms;
        self.evictions += victims.len() as u64;
        OomResponse::Evict { victims, planning_ms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::memory::TensorClass;
    use crate::model::transformer_profile;
    use crate::util::GIB;

    #[test]
    fn reactive_mode() {
        let p = transformer_profile(&ModelSpec::bert_tiny(), 2, 16, 1.0);
        let mut d = DtrPlanner::new();
        let dec = d.begin_iteration(&InputDesc::new(2, 16), &p);
        assert_eq!(dec.mode, IterationMode::Reactive);
    }

    #[test]
    fn evicts_lowest_heuristic_first() {
        let mut l = Ledger::new(GIB);
        // cheap-to-recompute big stale tensor = best victim
        let cheap_big = l.create(64 << 20, TensorClass::Activation, 0, 1.0).unwrap();
        let costly_small = l.create(1 << 20, TensorClass::Activation, 1, 100.0).unwrap();
        for _ in 0..10 {
            l.touch(costly_small); // keep it fresh
        }
        let mut d = DtrPlanner::new();
        match d.on_oom(&l, 32 << 20) {
            OomResponse::Evict { victims, planning_ms } => {
                assert_eq!(victims, vec![cheap_big]);
                assert!(planning_ms > 0.0);
            }
            OomResponse::Fail => panic!("should evict"),
        }
    }

    #[test]
    fn fails_when_not_enough_evictable() {
        let mut l = Ledger::new(GIB);
        let _ = l.create(1 << 20, TensorClass::Activation, 0, 1.0).unwrap();
        let mut d = DtrPlanner::new();
        assert!(matches!(d.on_oom(&l, 1 << 30), OomResponse::Fail));
    }

    #[test]
    fn fails_with_nothing_evictable() {
        let mut l = Ledger::new(GIB);
        let _ = l.create(1 << 20, TensorClass::Fixed, 0, 0.0).unwrap();
        let mut d = DtrPlanner::new();
        assert!(matches!(d.on_oom(&l, 1), OomResponse::Fail));
    }

    #[test]
    fn planning_cost_accumulates_per_oom() {
        let mut l = Ledger::new(GIB);
        for i in 0..20 {
            let _ = l.create(4 << 20, TensorClass::Activation, i, 1.0).unwrap();
        }
        let mut d = DtrPlanner::new();
        let _ = d.on_oom(&l, 8 << 20);
        let after_one = d.planning_ms_total;
        let _ = d.on_oom(&l, 8 << 20);
        assert!(d.planning_ms_total > after_one);
        assert!(d.evictions >= 2);
    }
}
