//! Adam optimizer over host-resident f32 parameter buffers (the real
//! engine's update step; the optimizer state is part of fixed_bytes in the
//! memory model: params + grads + m + v = 16 B/param).

#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        // BERT-finetune defaults (paper §6.6 uses 2e-5..5e-5).
        AdamConfig { lr: 3e-4, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

pub struct Adam {
    cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl Adam {
    pub fn new(n_params: usize, cfg: AdamConfig) -> Self {
        Adam { cfg, m: vec![0.0; n_params], v: vec![0.0; n_params], t: 0 }
    }

    pub fn step_count(&self) -> i32 {
        self.t
    }

    /// One update over the flat parameter/grad views.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        let lr = self.cfg.lr;
        for i in 0..params.len() {
            let g = grads[i] + self.cfg.weight_decay * params[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + self.cfg.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimises_quadratic() {
        // f(x) = (x-3)^2: Adam should converge to 3.
        let mut adam = Adam::new(1, AdamConfig { lr: 0.1, ..Default::default() });
        let mut x = vec![0.0f32];
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0)];
            adam.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "x={}", x[0]);
    }

    #[test]
    fn bias_correction_first_step() {
        // First step with grad g moves by ~lr regardless of g's magnitude.
        let mut adam = Adam::new(1, AdamConfig { lr: 0.01, ..Default::default() });
        let mut x = vec![1.0f32];
        adam.step(&mut x, &[1e-3]);
        assert!((1.0 - x[0] - 0.01).abs() < 1e-3, "step={}", 1.0 - x[0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let mut adam = Adam::new(2, AdamConfig::default());
        let mut x = vec![0.0f32; 2];
        adam.step(&mut x, &[0.0]);
    }
}
