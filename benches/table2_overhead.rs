//! Table 2: Mimose overhead breakdown at 6 GB — collector (2x forward for
//! ~10 iterations), estimator & scheduler (sub-millisecond, measured for
//! real), and the total normalised to single-iteration time
//! (paper: 3.95 iterations per epoch on average).

#[path = "common.rs"]
mod common;

use common::{rule, write_tsv};
use mimose::config::{ExperimentConfig, PlannerKind, Task};
use mimose::engine::sim::SimEngine;

fn main() {
    rule("Table 2 — Mimose overhead breakdown @ 6 GB (one epoch)");
    println!("{:<12} {:>12} {:>22} {:>14} {:>10}", "task", "collector", "estimator+scheduler", "total", "(iters)");
    let mut rows = Vec::new();
    let mut total_iters_overhead = Vec::new();
    for task in Task::all() {
        let budget = if task == Task::McRoberta { 4.0 } else { 6.0 };
        let mut cfg = ExperimentConfig::new(task, PlannerKind::Mimose, budget);
        cfg.max_iters = task.iters_per_epoch().min(3000); // epoch (capped for CI speed)
        let mut e = SimEngine::new(cfg).unwrap();
        let r = e.run_epoch();
        assert_eq!(r.oom_failures(), 0);

        let iter_ms = r.compute_ms() / r.iters.len() as f64;
        let collector_total = r.collector_ms();
        let collect_iters = r.iters.iter().filter(|m| m.collector_ms > 0.0).count();
        // per-generation cost: responsive cache-miss iterations only (cache
        // hits cost ~1 µs lookups; the paper's Table 2 counts generations)
        let plan_times: Vec<f64> = r
            .iters
            .iter()
            .filter(|m| !m.cache_hit && m.planning_ms > 0.0 && m.collector_ms == 0.0)
            .map(|m| m.planning_ms)
            .collect();
        let plan_min = plan_times.iter().copied().fold(f64::INFINITY, f64::min);
        let plan_max = plan_times.iter().copied().fold(0.0, f64::max);
        let total_overhead = collector_total + r.planning_ms();
        let overhead_iters = total_overhead / iter_ms;
        total_iters_overhead.push(overhead_iters);
        println!(
            "{:<12} {:>9.1} ms {:>9.3}-{:.3} ms {:>11.1} ms {:>7.2} it",
            task.name(),
            collector_total,
            plan_min.min(9.999),
            plan_max,
            total_overhead,
            overhead_iters,
        );
        println!(
            "  ({iter_ms:.1} ms/iter, collector x{collect_iters}, {} plans generated)",
            plan_times.len()
        );
        rows.push(format!(
            "{}\t{:.2}\t{:.4}\t{:.4}\t{:.2}\t{:.3}",
            task.name(), collector_total, plan_min, plan_max, total_overhead, overhead_iters
        ));
    }
    write_tsv(
        "table2_overhead",
        "task\tcollector_ms\tplan_min_ms\tplan_max_ms\ttotal_ms\toverhead_iters",
        &rows,
    );
    let avg = total_iters_overhead.iter().sum::<f64>() / total_iters_overhead.len() as f64;
    println!("\nmean total overhead: {avg:.2} iterations/epoch (paper: 3.95)");
    assert!(avg < 40.0, "overhead must stay a handful of iterations");
}
