//! Cross-planner integration + property tests over the SimEngine: the
//! system-level invariants that hold for ANY seed/task/budget.

use mimose::config::{ExperimentConfig, PlannerKind, Task};
use mimose::engine::sim::SimEngine;
use mimose::util::rng::Rng;
use mimose::util::GIB;

fn run(task: Task, kind: PlannerKind, budget: f64, iters: usize, seed: u64) -> mimose::metrics::RunReport {
    let mut cfg = ExperimentConfig::new(task, kind, budget);
    cfg.max_iters = iters;
    cfg.seed = seed;
    SimEngine::new(cfg).expect("fits").run_epoch()
}

#[test]
fn memory_safety_under_random_budgets() {
    // Property: Sublinear/Mimose/DTR never exceed the budget, for random
    // feasible budgets and seeds, on every task.
    let mut rng = Rng::new(99);
    for _ in 0..6 {
        let task = *rng.choose(&Task::all());
        let fixed_gb = task.model().fixed_state_bytes() as f64 / GIB as f64;
        let budget = fixed_gb + rng.range_f(1.6, 5.0);
        let seed = rng.next_u64();
        for kind in [PlannerKind::Sublinear, PlannerKind::Mimose, PlannerKind::Dtr] {
            let r = run(task, kind, budget, 120, seed);
            assert!(
                r.peak_bytes() <= (budget * GIB as f64) as u64,
                "{} {} @ {budget:.2} GB seed {seed}: peak {}",
                task.name(),
                kind.name(),
                r.peak_bytes()
            );
        }
    }
}

#[test]
fn ordering_invariant_more_budget_never_slower() {
    // For the same planner/seed, a larger budget can only reduce
    // recompute+planning time (weak monotonicity, allowing 2% noise).
    for kind in [PlannerKind::Sublinear, PlannerKind::Mimose] {
        let lo = run(Task::TcBert, kind, 5.0, 300, 7);
        let hi = run(Task::TcBert, kind, 7.0, 300, 7);
        let lo_over = lo.recompute_ms() + lo.planning_ms();
        let hi_over = hi.recompute_ms() + hi.planning_ms();
        assert!(
            hi_over <= lo_over * 1.02,
            "{}: overhead grew with budget ({lo_over} -> {hi_over})",
            kind.name()
        );
    }
}

#[test]
fn mimose_cache_stabilises_after_warmup() {
    let r = run(Task::McRoberta, PlannerKind::Mimose, 4.0, 400, 3);
    // after the first 100 iterations the hit rate of the tail must be high
    let tail = &r.iters[100..];
    let hits = tail.iter().filter(|m| m.cache_hit).count();
    assert!(
        hits as f64 / tail.len() as f64 > 0.8,
        "tail hit rate {}",
        hits as f64 / tail.len() as f64
    );
}

#[test]
fn baseline_is_fastest_when_memory_is_unlimited() {
    let base = run(Task::QaBert, PlannerKind::Baseline, 64.0, 200, 5);
    for kind in [PlannerKind::Sublinear, PlannerKind::Dtr, PlannerKind::Mimose] {
        let r = run(Task::QaBert, kind, 64.0, 200, 5);
        assert!(
            r.total_ms() >= base.total_ms() * 0.999,
            "{} beat baseline with unlimited memory",
            kind.name()
        );
    }
}

#[test]
fn deterministic_runs_for_same_seed() {
    let a = run(Task::TcBert, PlannerKind::Mimose, 6.0, 150, 11);
    let b = run(Task::TcBert, PlannerKind::Mimose, 6.0, 150, 11);
    assert_eq!(a.iters.len(), b.iters.len());
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(x.seqlen, y.seqlen);
        assert_eq!(x.peak_bytes, y.peak_bytes);
        assert_eq!(x.n_checkpointed, y.n_checkpointed);
    }
}

#[test]
fn dtr_recompute_grows_as_budget_shrinks() {
    let tight = run(Task::McRoberta, PlannerKind::Dtr, 3.3, 250, 2);
    let loose = run(Task::McRoberta, PlannerKind::Dtr, 3.8, 250, 2);
    assert!(tight.recompute_ms() > loose.recompute_ms());
    assert!(tight.planning_ms() >= loose.planning_ms());
}

#[test]
fn sublinear_plan_is_input_independent() {
    let r = run(Task::TcBert, PlannerKind::Sublinear, 5.0, 200, 13);
    let counts: std::collections::BTreeSet<usize> =
        r.iters.iter().map(|m| m.n_checkpointed).collect();
    assert_eq!(counts.len(), 1, "static planner must apply one plan: {counts:?}");
}

#[test]
fn mimose_plans_scale_with_input_size() {
    let r = run(Task::TcBert, PlannerKind::Mimose, 5.0, 400, 17);
    // correlation between seqlen and checkpointed count must be positive
    let resp: Vec<_> = r.iters.iter().filter(|m| m.collector_ms == 0.0).collect();
    let n = resp.len() as f64;
    let mx = resp.iter().map(|m| m.seqlen as f64).sum::<f64>() / n;
    let my = resp.iter().map(|m| m.n_checkpointed as f64).sum::<f64>() / n;
    let cov: f64 =
        resp.iter().map(|m| (m.seqlen as f64 - mx) * (m.n_checkpointed as f64 - my)).sum();
    assert!(cov > 0.0, "plans must grow with input size");
}
