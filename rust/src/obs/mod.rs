//! Crate-wide observability: a metrics registry + structured tracing.
//!
//! Mimose's claim is that online planning overhead stays negligible while
//! plans adapt to input dynamics (§4, Table 2) — this module is how the
//! repro *shows* it. Two global, independently-gated facilities:
//!
//! * **Metrics** ([`registry`]): named counters, gauges, and fixed-bucket
//!   histograms behind relaxed atomics. The hot subsystems increment them
//!   in place — plan caches (`plan_cache.hits/misses/evictions/purges`,
//!   `shared_cache.*`), the coordinator state machine
//!   (`coordinator.transitions/reshelters`, `estimator.refits`), the
//!   budget broker (`broker.path_full/path_incremental/clawbacks`), the
//!   engines (`engine.fwd_stages/bwd_stages/recompute_stages`), and the
//!   event core (`fleet.queue_depth` gauge, plus the chaos and
//!   multi-device counters `fleet.preemptions` / `fleet.forced_stops` /
//!   `fleet.migrations`).
//! * **Tracing** ([`trace`]): multi-track spans/instants with per-track
//!   logical clocks, exported as a Chrome-trace file via `--trace-out`
//!   (one Perfetto track per fleet job plus a broker track; multi-device
//!   fleets split the broker track into one `device<d>.broker` track per
//!   device so each device's fills and migration landings group visually).
//!
//! Both are **disabled by default and zero-cost when off**: every helper
//! checks one relaxed [`AtomicBool`] and returns before touching any lock
//! or map. Enable via `[obs]` TOML config, the `--obs`/`--trace-out` CLI
//! flags, or [`set_enabled`] in code. Recording through a registered
//! handle is a lone atomic RMW, so `util::threadpool` workers can hammer
//! the same counter without losing updates.

pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, HistogramSnapshot, Registry};
pub use trace::Tracer;

use crate::util::json::escape_str;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

static METRICS_ON: AtomicBool = AtomicBool::new(false);
static TRACE_ON: AtomicBool = AtomicBool::new(false);

/// Default latency histogram edges (ms) for [`observe_ms`].
pub const LATENCY_BOUNDS_MS: &[f64] = &[0.001, 0.01, 0.1, 1.0, 10.0, 100.0];

fn global_registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry::new()))
}

fn global_tracer() -> &'static Mutex<Tracer> {
    static TR: OnceLock<Mutex<Tracer>> = OnceLock::new();
    TR.get_or_init(|| Mutex::new(Tracer::default()))
}

/// Poison-tolerant lock: a panicking test thread must not wedge every
/// other observer of the global instruments.
fn lock<T>(m: &'static Mutex<T>) -> MutexGuard<'static, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// enable gates
// ---------------------------------------------------------------------------

pub fn set_metrics_enabled(on: bool) {
    METRICS_ON.store(on, Ordering::Relaxed);
}

pub fn set_trace_enabled(on: bool) {
    TRACE_ON.store(on, Ordering::Relaxed);
}

/// Flip metrics and tracing together.
pub fn set_enabled(on: bool) {
    set_metrics_enabled(on);
    set_trace_enabled(on);
}

pub fn metrics_enabled() -> bool {
    METRICS_ON.load(Ordering::Relaxed)
}

pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// metrics helpers (no-ops while metrics are disabled)
// ---------------------------------------------------------------------------

/// Register (or find) a counter regardless of the enable gate — for call
/// sites that cache the `'static` handle and guard recording themselves.
pub fn counter(name: &str) -> &'static Counter {
    lock(global_registry()).counter(name)
}

/// Register (or find) a latency histogram ([`LATENCY_BOUNDS_MS`] buckets)
/// regardless of the enable gate — the handle-caching analogue of
/// [`counter`] for hot paths that record with [`Histogram::observe_ms`].
pub fn latency_histogram(name: &str) -> &'static Histogram {
    lock(global_registry()).histogram(name, LATENCY_BOUNDS_MS)
}

pub fn inc(name: &str) {
    if metrics_enabled() {
        lock(global_registry()).counter(name).inc();
    }
}

pub fn add(name: &str, n: u64) {
    if metrics_enabled() {
        lock(global_registry()).counter(name).add(n);
    }
}

pub fn gauge_set(name: &str, v: u64) {
    if metrics_enabled() {
        lock(global_registry()).gauge(name).set(v);
    }
}

/// Record a latency sample into a fixed-bucket histogram (registered on
/// first use with [`LATENCY_BOUNDS_MS`]).
pub fn observe_ms(name: &str, ms: f64) {
    if metrics_enabled() {
        lock(global_registry()).histogram(name, LATENCY_BOUNDS_MS).observe_ms(ms);
    }
}

/// Current value of a counter (0 if never registered). Reads are not
/// gated: a disabled registry still reports whatever was recorded.
pub fn counter_value(name: &str) -> u64 {
    lock(global_registry()).counter_value(name)
}

pub fn gauge_value(name: &str) -> u64 {
    lock(global_registry()).gauge_value(name)
}

/// Snapshot of every counter, name-sorted.
pub fn counters() -> Vec<(String, u64)> {
    lock(global_registry()).counters()
}

/// Zero all metrics and drop all trace events (instrument registrations
/// and track-naming survive only as fresh state).
pub fn reset() {
    lock(global_registry()).reset();
    lock(global_tracer()).clear();
}

// ---------------------------------------------------------------------------
// tracing helpers (no-ops while tracing is disabled)
// ---------------------------------------------------------------------------

/// Run `f` against the global tracer iff tracing is enabled.
pub fn with_tracer<F: FnOnce(&mut Tracer)>(f: F) {
    if trace_enabled() {
        f(&mut lock(global_tracer()));
    }
}

/// Serialise the global trace to Chrome trace-event JSON.
pub fn trace_json() -> String {
    lock(global_tracer()).to_json()
}

/// Number of buffered trace events.
pub fn trace_len() -> usize {
    lock(global_tracer()).len()
}

/// Write the global trace to `path` (Chrome trace-event JSON; open in
/// Perfetto or `chrome://tracing`).
pub fn write_trace(path: &str) -> std::io::Result<()> {
    std::fs::write(path, trace_json())
}

// ---------------------------------------------------------------------------
// export
// ---------------------------------------------------------------------------

/// The `obs` section: every counter, gauge, and histogram as one JSON
/// object (parseable by `util::json`; merged into `BENCH_*.json`).
pub fn metrics_json() -> String {
    let reg = lock(global_registry());
    let mut out = String::from("{\"counters\":{");
    for (i, (name, v)) in reg.counters().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":{}", escape_str(name), v));
    }
    out.push_str("},\"gauges\":{");
    for (i, (name, v, high)) in reg.gauges().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\"{}\":{{\"value\":{},\"high_water\":{}}}",
            escape_str(name),
            v,
            high
        ));
    }
    out.push_str("},\"histograms\":{");
    for (i, (name, h)) in reg.histograms().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let bounds: Vec<String> = h.bounds.iter().map(|b| format!("{b}")).collect();
        let buckets: Vec<String> = h.buckets.iter().map(|c| c.to_string()).collect();
        out.push_str(&format!(
            "\"{}\":{{\"count\":{},\"sum_ms\":{:.6},\"bounds\":[{}],\"buckets\":[{}]}}",
            escape_str(name),
            h.count,
            h.sum_ms,
            bounds.join(","),
            buckets.join(",")
        ));
    }
    out.push_str("}}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// The enable flags and instruments are process-global; tests that
    /// toggle or read them must not interleave.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn serial() -> MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_helpers_are_noops() {
        let _g = serial();
        set_enabled(false);
        reset();
        inc("obs.test.disabled");
        add("obs.test.disabled", 10);
        gauge_set("obs.test.disabled_gauge", 5);
        observe_ms("obs.test.disabled_hist", 1.0);
        with_tracer(|tr| tr.push_span("never", "test", 1.0, &[]));
        assert_eq!(counter_value("obs.test.disabled"), 0);
        assert_eq!(gauge_value("obs.test.disabled_gauge"), 0);
        assert_eq!(trace_len(), 0);
    }

    #[test]
    fn enabled_helpers_record_and_reset_clears() {
        let _g = serial();
        set_enabled(true);
        reset();
        inc("obs.test.basic");
        add("obs.test.basic", 2);
        gauge_set("obs.test.depth", 7);
        gauge_set("obs.test.depth", 3);
        observe_ms("obs.test.lat", 0.5);
        with_tracer(|tr| tr.push_span("iter", "test", 1.0, &[("x", 1.0)]));
        assert_eq!(counter_value("obs.test.basic"), 3);
        assert_eq!(gauge_value("obs.test.depth"), 3);
        assert!(trace_len() >= 1);
        let v = Json::parse(&metrics_json()).expect("obs section must parse");
        assert_eq!(
            v.req("counters").req("obs.test.basic").as_f64(),
            Some(3.0)
        );
        assert_eq!(
            v.req("gauges").req("obs.test.depth").req("high_water").as_f64(),
            Some(7.0)
        );
        let h = v.req("histograms").req("obs.test.lat");
        assert_eq!(h.req("count").as_f64(), Some(1.0));
        set_enabled(false);
        reset();
        assert_eq!(counter_value("obs.test.basic"), 0);
        assert_eq!(trace_len(), 0);
    }

    #[test]
    fn metrics_and_trace_gates_are_independent() {
        let _g = serial();
        set_metrics_enabled(true);
        set_trace_enabled(false);
        reset();
        inc("obs.test.gates");
        with_tracer(|tr| tr.instant("no", "test", &[]));
        assert_eq!(counter_value("obs.test.gates"), 1);
        assert_eq!(trace_len(), 0, "trace gate off: nothing buffered");
        set_metrics_enabled(false);
        set_trace_enabled(true);
        inc("obs.test.gates");
        with_tracer(|tr| tr.instant("yes", "test", &[]));
        assert_eq!(counter_value("obs.test.gates"), 1, "metrics gate off");
        assert_eq!(trace_len(), 1);
        set_enabled(false);
        reset();
    }
}
