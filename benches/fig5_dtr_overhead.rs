//! Figure 5: DTR's training-time breakdown on MC-Roberta (SWAG). The paper
//! measures planning at 4.40% of iteration time on average (6.06% max, at
//! the tightest budget) plus up to 20.7% recompute, and actual memory use
//! far above the nominal budget due to fragmentation.

#[path = "common.rs"]
mod common;

use common::{gb, rule, write_tsv};
use mimose::config::{ExperimentConfig, PlannerKind, Task};
use mimose::engine::sim::SimEngine;

const ITERS: usize = 400;

fn main() {
    rule("Fig 5 — DTR time breakdown, MC-Roberta (SWAG)");
    println!("budget   compute%  recompute%  planning%  reserved(actual)  evictions");
    let mut rows = Vec::new();
    let mut shares = Vec::new();
    for budget in [3.3f64, 3.4, 3.5, 3.6] {
        let mut cfg = ExperimentConfig::new(Task::McRoberta, PlannerKind::Dtr, budget);
        cfg.max_iters = ITERS;
        let mut e = SimEngine::new(cfg).expect("engine");
        let r = e.run_epoch();
        let total = r.total_ms();
        let reserved = r.iters.iter().map(|m| m.frag_bytes + m.peak_bytes).max().unwrap_or(0);
        println!(
            "{:4.1} GB   {:6.2}%   {:7.2}%   {:7.2}%     {:6.2} GB        {}",
            budget,
            r.compute_ms() / total * 100.0,
            r.recompute_share() * 100.0,
            r.planning_share() * 100.0,
            gb(reserved),
            r.iters.iter().map(|m| m.n_checkpointed).sum::<usize>(),
        );
        rows.push(format!(
            "{budget}\t{:.4}\t{:.4}\t{:.4}\t{:.4}",
            r.compute_ms() / total,
            r.recompute_share(),
            r.planning_share(),
            gb(reserved)
        ));
        shares.push(r.planning_share());
    }
    write_tsv("fig5_dtr_breakdown", "budget_gb\tcompute\trecompute\tplanning\treserved_gb", &rows);
    // paper shape: tighter budget => more planning overhead
    assert!(
        shares.first().unwrap() >= shares.last().unwrap(),
        "planning share should grow as the budget tightens: {shares:?}"
    );
    println!("\npaper reference: planning 4.40% avg / 6.06% max; recompute up to 20.7%");
}
