//! Training-state save/restore for the real engine: a small self-describing
//! binary format (magic, version, named f32 sections with checksums) so
//! long real runs can resume — and so planner state is reproducible.
//!
//! Format:
//!   "MIMO" u32_version u32_nsections
//!   per section: u16 name_len, name bytes, u64 elem count, fnv64 of data,
//!                f32 data (LE)

use crate::util::error::Result;
use crate::{anyhow, bail};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MIMO";
const VERSION: u32 = 1;

fn fnv64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn f32s_as_bytes(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Write named f32 sections.
pub fn save(path: &Path, sections: &[(&str, &[f32])]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(sections.len() as u32).to_le_bytes())?;
    for (name, data) in sections {
        let nb = name.as_bytes();
        if nb.len() > u16::MAX as usize {
            bail!("section name too long");
        }
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&(data.len() as u64).to_le_bytes())?;
        let bytes = f32s_as_bytes(data);
        f.write_all(&fnv64(bytes).to_le_bytes())?;
        f.write_all(bytes)?;
    }
    Ok(())
}

/// Read all sections back as (name, data).
pub fn load(path: &Path) -> Result<Vec<(String, Vec<f32>)>> {
    let mut f = std::fs::File::open(path)?;
    let mut hdr = [0u8; 4];
    f.read_exact(&mut hdr)?;
    if &hdr != MAGIC {
        bail!("bad magic");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    f.read_exact(&mut u32b)?;
    let n = u32::from_le_bytes(u32b) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut u16b = [0u8; 2];
        f.read_exact(&mut u16b)?;
        let mut name = vec![0u8; u16::from_le_bytes(u16b) as usize];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|_| anyhow!("bad section name"))?;
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let count = u64::from_le_bytes(u64b) as usize;
        f.read_exact(&mut u64b)?;
        let want_sum = u64::from_le_bytes(u64b);
        let mut bytes = vec![0u8; count * 4];
        f.read_exact(&mut bytes)?;
        if fnv64(&bytes) != want_sum {
            bail!("checksum mismatch in section '{name}'");
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mimose_ckpt_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let a: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        let b = vec![-1.0f32, f32::MAX, f32::MIN_POSITIVE];
        save(&p, &[("params", &a), ("adam.m", &b)]).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "params");
        assert_eq!(back[0].1, a);
        assert_eq!(back[1].1, b);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt");
        save(&p, &[("x", &[1.0f32, 2.0, 3.0])]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF;
        std::fs::write(&p, bytes).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("checksum"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load(&p).is_err());
        let _ = std::fs::remove_file(p);
    }
}
