"""Pure-jnp reference oracles for the Pallas kernels and fused layers.

These are the ground truth for pytest/hypothesis: the Pallas kernel(s) in this
package must match them bit-for-tolerance, and the manual VJPs in layers.py
are validated against jax.grad of these functions.
"""

import jax
import jax.numpy as jnp

GELU_C = 0.7978845608028654  # sqrt(2/pi)


def gelu(x):
    """tanh-approximation GELU (the BERT/HF default)."""
    return 0.5 * x * (1.0 + jnp.tanh(GELU_C * (x + 0.044715 * x**3)))


def gelu_grad(x):
    """d gelu(x) / dx for the tanh approximation."""
    inner = GELU_C * (x + 0.044715 * x**3)
    t = jnp.tanh(inner)
    dinner = GELU_C * (1.0 + 3 * 0.044715 * x**2)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * dinner


def layernorm(x, g, b, eps=1e-5):
    """LayerNorm over the last axis."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + eps)
    return xhat * g + b


def softmax(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention(q, k, v, scale=None):
    """Eager (memory-quadratic) multi-head attention core.

    q, k, v: [B, H, S, D]. Materialises the [B, H, S, S] score and prob
    tensors exactly as PyTorch eager does — this quadratic term is the memory
    behaviour Mimose's estimator models (paper Sec 4.3, Fig 8).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(d))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    p = softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def attention_with_probs(q, k, v, scale=None):
    """Same as attention() but also returns the prob tensor (a residual)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(d))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    p = softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v), p
