//! Figure 10: per-stage activation memory — Swin-Transformer's patch-merging
//! step-down vs ResNet's stem-dominated curve (why the scheduler treats
//! "stages" as natural separators, §4.4).

#[path = "common.rs"]
mod common;

use common::{rule, write_tsv};
use mimose::model::vision::{ResNetSpec, SwinSpec};

fn main() {
    rule("Fig 10a — Swin-T per-block activation bytes by stage");
    let swin = SwinSpec::default().profile(8, 224);
    let mut rows = Vec::new();
    for l in swin.layers() {
        let mb = l.act_bytes as f64 / 1048576.0;
        println!("  {:<16} {:8.1} MiB  |{}", l.name, mb, "#".repeat((mb / 8.0) as usize));
        rows.push(format!("swin\t{}\t{:.2}", l.name, mb));
    }

    rule("Fig 10b — ResNet-50 per-block activation bytes by stage");
    let resnet = ResNetSpec::default().profile(8, 224);
    for l in resnet.layers() {
        let mb = l.act_bytes as f64 / 1048576.0;
        println!("  {:<16} {:8.1} MiB  |{}", l.name, mb, "#".repeat((mb / 8.0) as usize));
        rows.push(format!("resnet\t{}\t{:.2}", l.name, mb));
    }
    write_tsv("fig10_stage_memory", "model\tlayer\tact_mib", &rows);

    // paper shape checks: swin steps down ~50% per stage; resnet stage-1 has
    // its own structure (stem) breaking the monotone trend
    let s = SwinSpec::default().stage_block_bytes(224);
    for w in s.windows(2) {
        let r = w[1] as f64 / w[0] as f64;
        assert!((0.35..0.7).contains(&r), "swin step-down ratio {r}");
    }
    println!("\nswin stage ratios: {:?}", s.windows(2).map(|w| w[1] as f64 / w[0] as f64).collect::<Vec<_>>());
}
