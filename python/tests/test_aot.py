"""AOT path tests: manifest consistency, HLO text sanity, fingerprinting."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.configs import TINY

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_fingerprint_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()


def test_build_artifacts_inventory():
    names = [a[0] for a in aot.build_artifacts(TINY, 16)]
    assert names == ["embed_fwd", "embed_bwd", "block_fwd", "block_bwd",
                     "block_bwd_rc", "block_fwd_flash", "head_step"]


def test_block_fwd_artifact_io_contract():
    for name, fn, args, outs in aot.build_artifacts(TINY, 16):
        if name != "block_fwd":
            continue
        assert [n for n, _ in args] == model.BLOCK_PARAMS + ["x"]
        assert outs == ["y"] + model.RESIDUALS
        # the artifact fn must actually run on concrete zeros
        concrete = [jnp.zeros(s.shape, s.dtype) for _, s in args]
        res = fn(*concrete)
        assert len(res) == len(outs)


def test_block_bwd_artifact_grad_count():
    for name, fn, args, outs in aot.build_artifacts(TINY, 16):
        if name in ("block_bwd", "block_bwd_rc"):
            assert outs[0] == "gx"
            assert outs[1:] == ["g_" + n for n in model.BLOCK_PARAMS]


def test_hlo_text_is_parsable_format():
    """Lowered text must be XLA HLO text (entry computation, f32 types)."""
    gen = aot.build_artifacts(TINY, 16)
    name, fn, args, outs = next(gen)  # embed_fwd
    lowered = jax.jit(fn).lower(*[s for _, s in args])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f32[" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_configs_present(self, manifest):
        assert "bert-tiny" in manifest["configs"]

    def test_every_artifact_file_exists(self, manifest):
        for cfg in manifest["configs"].values():
            for a in cfg["artifacts"]:
                assert os.path.exists(os.path.join(ART, a["file"])), a["file"]

    def test_manifest_shapes_match_specs(self, manifest):
        cfg = manifest["configs"]["bert-tiny"]
        m = cfg["model"]
        assert m["hidden"] == TINY.hidden and m["layers"] == TINY.layers
        for a in cfg["artifacts"]:
            if a["name"] == "block_fwd":
                x = [i for i in a["inputs"] if i["name"] == "x"][0]
                assert x["shape"] == [TINY.batch, a["seq"], TINY.hidden]
                assert x["dtype"] == "f32"

    def test_param_count_recorded(self, manifest):
        assert manifest["configs"]["bert-tiny"]["model"]["param_count"] == TINY.param_count()
