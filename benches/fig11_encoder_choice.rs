//! Figure 11: peak memory when checkpointing encoder k of Bert-base — early
//! encoders are restored late in the backward pass (when most activations
//! are freed), so checkpointing them lowers peak the most.

#[path = "common.rs"]
mod common;

use common::{gb, rule, write_tsv};
use mimose::config::ModelSpec;
use mimose::model::transformer_profile;

fn main() {
    rule("Fig 11 — peak memory vs which encoder is checkpointed (Bert-base)");
    let model = ModelSpec::bert_base();
    let mut rows = Vec::new();
    println!("          seqlen128  seqlen256  seqlen384");
    for enc in 0..model.layers {
        let mut line = format!("encoder{:2}", enc);
        for seq in [128usize, 256, 384] {
            let p = transformer_profile(&model, 16, seq, 1.0);
            let peak = p.peak_bytes(&[enc + 1]); // layer ids: 0 = embed
            line.push_str(&format!("  {:7.2}GB", gb(peak)));
            rows.push(format!("{enc}\t{seq}\t{:.4}", gb(peak)));
        }
        println!("{line}");
    }
    for seq in [128usize, 256, 384] {
        let p = transformer_profile(&model, 16, seq, 1.0);
        println!("none      @{seq}: {:.2} GB", gb(p.peak_bytes(&[])));
    }
    write_tsv("fig11_encoder_choice", "encoder\tseqlen\tpeak_gb", &rows);

    // paper shape: peak is non-decreasing in encoder index
    let p = transformer_profile(&model, 16, 256, 1.0);
    let first = p.peak_bytes(&[1]);
    let last = p.peak_bytes(&[model.layers]);
    assert!(first < last, "checkpointing the first encoder must beat the last");
    println!("\nfirst-vs-last encoder peak delta @256: {:.2} GB", gb(last - first));
}
