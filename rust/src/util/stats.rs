//! Small statistics toolkit: summaries, percentiles, histograms, linear fits.
//! Used by metrics reporting and the bench harness (criterion stand-in).

/// Online mean/variance (Welford) plus min/max.
///
/// Every accessor is **total**: on an empty summary `mean`/`min`/`max`/
/// `variance`/`std` all return 0.0 (never NaN or ±infinity), and a
/// single-element summary reports that element as mean/min/max with zero
/// variance.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 { self.n }

    /// Mean of the samples; 0.0 when empty.
    pub fn mean(&self) -> f64 { self.mean }

    /// Smallest sample; 0.0 when empty (never +infinity).
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    /// Largest sample; 0.0 when empty (never -infinity).
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Sample variance (n-1 denominator); 0.0 for fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 { self.variance().sqrt() }
}

/// Exact percentile over a stored sample set (fine at bench scale).
///
/// Total on degenerate inputs: every quantile of an empty set is 0.0 (no
/// panic), and every quantile of a single-element set is that element.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self { Self::default() }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize { self.xs.len() }
    pub fn is_empty(&self) -> bool { self.xs.is_empty() }

    /// q in [0,1] (clamped); linear interpolation between order
    /// statistics. 0.0 on an empty sample set.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
        if lo == hi {
            self.xs[lo]
        } else {
            let w = pos - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 { self.quantile(0.5) }
    pub fn p99(&mut self) -> f64 { self.quantile(0.99) }
}

/// Fixed-bin histogram over [lo, hi); overflow/underflow clamp to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins] }
    }

    pub fn add(&mut self, x: f64) {
        let f = (x - self.lo) / (self.hi - self.lo);
        let i = ((f * self.bins.len() as f64) as isize)
            .clamp(0, self.bins.len() as isize - 1) as usize;
        self.bins[i] += 1;
    }

    pub fn bins(&self) -> &[u64] { &self.bins }
    pub fn total(&self) -> u64 { self.bins.iter().sum() }

    /// Midpoint of bin i.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Render an ASCII bar chart (used by fig benches for paper-like plots).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let bar = "#".repeat((c as usize * width / max as usize).max(usize::from(c > 0)));
            out.push_str(&format!("{:8.1} |{:<w$}| {}\n", self.center(i), bar, c, w = width));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in [10.0, 20.0, 30.0, 40.0] {
            p.add(x);
        }
        assert!((p.median() - 25.0).abs() < 1e-12);
        assert_eq!(p.quantile(0.0), 10.0);
        assert_eq!(p.quantile(1.0), 40.0);
    }

    #[test]
    fn empty_summary_is_total() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0, "no +inf leak from the identity element");
        assert_eq!(s.max(), 0.0, "no -inf leak from the identity element");
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert!(!s.std().is_nan());
    }

    #[test]
    fn single_element_summary() {
        let mut s = Summary::new();
        s.add(7.5);
        assert_eq!(s.count(), 1);
        assert_eq!(s.mean(), 7.5);
        assert_eq!(s.min(), 7.5);
        assert_eq!(s.max(), 7.5);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn empty_percentiles_are_total() {
        let mut p = Percentiles::new();
        assert!(p.is_empty());
        assert_eq!(p.quantile(0.5), 0.0, "empty quantile must not panic");
        assert_eq!(p.median(), 0.0);
        assert_eq!(p.p99(), 0.0);
    }

    #[test]
    fn single_element_percentiles() {
        let mut p = Percentiles::new();
        p.add(42.0);
        assert_eq!(p.quantile(0.0), 42.0);
        assert_eq!(p.median(), 42.0);
        assert_eq!(p.quantile(1.0), 42.0);
        // out-of-range q clamps rather than indexing out of bounds
        assert_eq!(p.quantile(-1.0), 42.0);
        assert_eq!(p.quantile(2.0), 42.0);
    }

    #[test]
    fn histogram_bins_and_clamp() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [0.5, 1.5, 9.9, 42.0, -3.0] {
            h.add(x);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.bins()[0], 3); // 0.5, 1.5, -3.0(clamped)
        assert_eq!(h.bins()[4], 2); // 9.9, 42(clamped)
        assert!((h.center(0) - 1.0).abs() < 1e-12);
    }
}
