//! Input pipeline: dataset input dynamics + synthetic corpus.
//!
//! The paper's input dynamics (Fig 3) come from dataset diversity plus
//! augmentation: per-sample token lengths vary; a mini-batch pads to its
//! longest sample, so the *collated* seqlen is the max over the batch. We
//! model the three NLP datasets with distribution-faithful samplers
//! (ranges/shapes from Fig 3), plus the graph-era extension workloads:
//! seq2seq draws two *independent* collated lengths per mini-batch (source
//! and target pad separately), and vision draws ONE resolution for the
//! whole batch (random-resize augmentation). A synthetic corpus feeds the
//! real PJRT training path.

pub mod corpus;
pub mod tokenizer;
pub mod trace;

pub use corpus::{Corpus, CorpusConfig};
pub use tokenizer::Tokenizer;
pub use trace::{Interarrival, JobLength, TraceConfig};

use crate::config::Task;
use crate::util::rng::Rng;

/// Per-sample token-length distribution of a dataset.
#[derive(Clone, Copy, Debug)]
pub enum LengthDist {
    /// Normal(mean, std), clamped to [lo, hi] — SWAG, SQuAD, WMT.
    Normal { mean: f64, std: f64, lo: usize, hi: usize },
    /// Bounded power-law (many short questions, few long) — GLUE-QQP.
    PowerLaw { alpha: f64, lo: usize, hi: usize },
    /// Uniform over [lo, hi] rounded to a multiple of `step` — resize
    /// augmentation (Detectron-style multi-scale resolutions).
    UniformStep { lo: usize, hi: usize, step: usize },
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LengthDist::Normal { mean, std, lo, hi } => {
                (rng.normal_in(mean, std).round() as i64).clamp(lo as i64, hi as i64) as usize
            }
            LengthDist::PowerLaw { alpha, lo, hi } => {
                rng.power_law(lo as f64, hi as f64, alpha).round() as usize
            }
            LengthDist::UniformStep { lo, hi, step } => {
                let raw = rng.range_u(lo, hi);
                (raw / step.max(1)).max(1) * step.max(1)
            }
        }
    }

    /// Table 1 / Fig 3 dataset parameters (primary axis).
    pub fn for_task(task: Task) -> LengthDist {
        match task {
            // SWAG: short commonsense sentences, collated range 35-141
            Task::McRoberta => LengthDist::Normal { mean: 55.0, std: 16.0, lo: 20, hi: 141 },
            // SQuAD: long paragraphs, collated range 153-512
            Task::QaXlnet | Task::QaBert => {
                LengthDist::Normal { mean: 180.0, std: 60.0, lo: 120, hi: 512 }
            }
            // QQP: question pairs, power-law, collated range 30-332
            Task::TcBert => LengthDist::PowerLaw { alpha: 2.2, lo: 25, hi: 332 },
            // WMT-style source sentences, collated range ~120-400
            Task::Seq2seq => LengthDist::Normal { mean: 140.0, std: 45.0, lo: 60, hi: 400 },
            // multi-scale resize augmentation: 192..288 px in steps of 16
            Task::Swin => LengthDist::UniformStep { lo: 192, hi: 288, step: 16 },
            // segmentation resize augmentation: 128..256 px on the 32-px
            // grid (every U-Net level halves evenly — the smooth curve)
            Task::Unet => LengthDist::UniformStep { lo: 128, hi: 256, step: 32 },
        }
    }

    /// Secondary-axis distribution (seq2seq target lengths); `None` for
    /// single-axis tasks. Sampled independently of the source lengths —
    /// exactly the 2-D input dynamics the estimator's `InputKey` carries.
    pub fn secondary_for_task(task: Task) -> Option<LengthDist> {
        match task {
            Task::Seq2seq => {
                Some(LengthDist::Normal { mean: 115.0, std: 40.0, lo: 50, hi: 400 })
            }
            _ => None,
        }
    }
}

/// Tokenise -> pad -> truncate -> collate: returns the mini-batch seqlen
/// (max over per-sample lengths, truncated to the model's max).
pub fn collate_seqlen(dist: &LengthDist, batch: usize, max_seq: usize, rng: &mut Rng) -> usize {
    (0..batch)
        .map(|_| dist.sample(rng))
        .max()
        .unwrap_or(1)
        .min(max_seq)
}

/// An epoch's worth of collated input shapes for a task.
pub struct InputStream {
    dist: LengthDist,
    /// Secondary-axis distribution (seq2seq target side).
    dist2: Option<LengthDist>,
    batch: usize,
    max_seq: usize,
    /// One draw covers the whole mini-batch (vision: every image in the
    /// batch is resized to the same resolution — no collate max).
    whole_batch: bool,
    rng: Rng,
}

impl InputStream {
    pub fn new(task: Task, seed: u64) -> Self {
        Self::with_batch(task, task.batch(), seed)
    }

    /// [`InputStream::new`] with an explicit collated batch size — fleet
    /// tenants may override the task's Table 1 batch per job, which changes
    /// the collate max (larger batches skew long).
    pub fn with_batch(task: Task, batch: usize, seed: u64) -> Self {
        InputStream {
            dist: LengthDist::for_task(task),
            dist2: LengthDist::secondary_for_task(task),
            batch,
            max_seq: task.model().max_seq,
            whole_batch: matches!(task, Task::Swin | Task::Unet),
            rng: Rng::new(seed),
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Next collated input shape: (primary, secondary); secondary is 0 for
    /// single-axis tasks.
    pub fn next_shape(&mut self) -> (usize, usize) {
        let primary = if self.whole_batch {
            self.dist.sample(&mut self.rng).min(self.max_seq)
        } else {
            collate_seqlen(&self.dist, self.batch, self.max_seq, &mut self.rng)
        };
        let secondary = match &self.dist2 {
            Some(d) => collate_seqlen(d, self.batch, self.max_seq, &mut self.rng),
            None => 0,
        };
        (primary, secondary)
    }

    /// Next collated primary-axis length (classic 1-D view; a seq2seq
    /// stream still advances both axes to stay deterministic).
    pub fn next_seqlen(&mut self) -> usize {
        self.next_shape().0
    }
}

impl Iterator for InputStream {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        Some(self.next_seqlen())
    }
}

/// Pad a true seqlen up to the nearest AOT bucket (the real engine's static
/// shapes). Returns None if the input exceeds all buckets (truncate first).
pub fn bucket_for(seqlen: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= seqlen).min()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn collated_ranges_match_fig3() {
        // Collated (batch-max) seqlens must land in the paper's ranges.
        for task in Task::all() {
            let mut s = InputStream::new(task, 7);
            let (lo, hi) = task.seq_range();
            let mut summary = Summary::new();
            for _ in 0..2000 {
                let x = s.next_seqlen();
                summary.add(x as f64);
                assert!(x <= task.model().max_seq);
            }
            // central mass within the paper's [lo, hi]
            assert!(
                summary.mean() >= lo as f64 && summary.mean() <= hi as f64,
                "{}: mean {} outside [{lo},{hi}]",
                task.name(),
                summary.mean()
            );
            assert!(summary.max() as usize <= hi + hi / 5, "{}: max {}", task.name(), summary.max());
        }
    }

    #[test]
    fn qqp_is_right_skewed() {
        // power law: mean > median
        let mut s = InputStream::new(Task::TcBert, 3);
        let mut v: Vec<f64> = (0..4000).map(|_| s.next_seqlen() as f64).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = v[v.len() / 2];
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean > median, "mean {mean} median {median}");
    }

    #[test]
    fn repeated_sizes_occur() {
        // §3.2: input sizes repeat — the premise of the plan cache.
        let mut s = InputStream::new(Task::McRoberta, 11);
        let mut seen = std::collections::HashMap::new();
        for _ in 0..1000 {
            *seen.entry(s.next_seqlen()).or_insert(0usize) += 1;
        }
        let repeats = seen.values().filter(|&&c| c > 1).count();
        assert!(repeats > seen.len() / 2, "most sizes should repeat");
    }

    #[test]
    fn deterministic_stream() {
        let a: Vec<usize> = InputStream::new(Task::QaBert, 5).take(50).collect();
        let b: Vec<usize> = InputStream::new(Task::QaBert, 5).take(50).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn seq2seq_shapes_are_two_axis_and_in_range() {
        let mut s = InputStream::new(Task::Seq2seq, 13);
        let (plo, phi) = Task::Seq2seq.seq_range();
        let (slo, shi) = Task::Seq2seq.seq2_range().unwrap();
        let mut psum = Summary::new();
        let mut ssum = Summary::new();
        for _ in 0..2000 {
            let (p, sec) = s.next_shape();
            assert!(sec > 0, "seq2seq must carry a target axis");
            psum.add(p as f64);
            ssum.add(sec as f64);
        }
        assert!(psum.mean() >= plo as f64 && psum.mean() <= phi as f64, "src mean {}", psum.mean());
        assert!(ssum.mean() >= slo as f64 && ssum.mean() <= shi as f64, "tgt mean {}", ssum.mean());
    }

    #[test]
    fn seq2seq_axes_vary_independently() {
        // correlation between collated src and tgt must be near zero —
        // they are drawn from independent per-sample distributions
        let mut s = InputStream::new(Task::Seq2seq, 17);
        let shapes: Vec<(f64, f64)> =
            (0..3000).map(|_| { let (p, t) = s.next_shape(); (p as f64, t as f64) }).collect();
        let n = shapes.len() as f64;
        let mx = shapes.iter().map(|x| x.0).sum::<f64>() / n;
        let my = shapes.iter().map(|x| x.1).sum::<f64>() / n;
        let cov = shapes.iter().map(|(x, y)| (x - mx) * (y - my)).sum::<f64>() / n;
        let sx = (shapes.iter().map(|(x, _)| (x - mx).powi(2)).sum::<f64>() / n).sqrt();
        let sy = (shapes.iter().map(|(_, y)| (y - my).powi(2)).sum::<f64>() / n).sqrt();
        let corr = cov / (sx * sy);
        assert!(corr.abs() < 0.1, "src/tgt correlation {corr}");
        // and the marginal collated distributions genuinely differ
        assert!((mx - my).abs() > 10.0, "src {mx} vs tgt {my}");
    }

    #[test]
    fn swin_draws_stepped_resolutions_per_batch() {
        let mut s = InputStream::new(Task::Swin, 23);
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..500 {
            let (p, sec) = s.next_shape();
            assert_eq!(sec, 0, "vision is single-axis");
            assert!(p >= 192 && p <= 288, "resolution {p} out of range");
            assert_eq!(p % 16, 0, "resolution {p} off the step grid");
            distinct.insert(p);
        }
        // whole-batch draw: the collate max must NOT pin every batch at the
        // top of the range (which per-sample max over batch 32 would do)
        assert!(distinct.len() >= 4, "saw only {distinct:?}");
    }

    #[test]
    fn unet_draws_whole_batch_resolutions_on_the_32px_grid() {
        let mut s = InputStream::new(Task::Unet, 29);
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..500 {
            let (p, sec) = s.next_shape();
            assert_eq!(sec, 0, "unet is single-axis");
            assert!(p >= 128 && p <= 256, "resolution {p} out of range");
            assert_eq!(p % 32, 0, "resolution {p} off the 32-px grid");
            distinct.insert(p);
        }
        assert!(distinct.len() >= 4, "saw only {distinct:?}");
    }

    #[test]
    fn one_d_tasks_have_zero_secondary() {
        for task in Task::all() {
            let mut s = InputStream::new(task, 3);
            for _ in 0..20 {
                assert_eq!(s.next_shape().1, 0);
            }
        }
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(17, &[16, 32, 64]), Some(32));
        assert_eq!(bucket_for(16, &[16, 32, 64]), Some(16));
        assert_eq!(bucket_for(65, &[16, 32, 64]), None);
    }

    #[test]
    fn bigger_batch_shifts_collated_max_up() {
        let dist = LengthDist::for_task(Task::TcBert);
        let mut rng1 = Rng::new(1);
        let mut rng2 = Rng::new(1);
        let small: f64 = (0..500)
            .map(|_| collate_seqlen(&dist, 4, 512, &mut rng1) as f64)
            .sum::<f64>()
            / 500.0;
        let large: f64 = (0..500)
            .map(|_| collate_seqlen(&dist, 32, 512, &mut rng2) as f64)
            .sum::<f64>()
            / 500.0;
        assert!(large > small);
    }
}
