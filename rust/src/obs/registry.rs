//! The metrics registry: named counters, gauges, and fixed-bucket
//! histograms behind relaxed atomics.
//!
//! Instrument handles are `&'static` references into a leak-allocated
//! registry, so recording is a single `fetch_add` with no lock held —
//! safe to hammer from `util::threadpool` workers without losing updates.
//! Registration (name -> handle) goes through one mutex; hot paths either
//! cache the handle or pay one uncontended lock per record via the
//! `obs::inc`/`obs::add` convenience helpers, both of which are no-ops
//! while metrics are disabled (see the module docs in [`crate::obs`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone event counter.
#[derive(Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins level (queue depths, live-tenant counts). Also tracks
/// the high-water mark since the last reset.
#[derive(Debug)]
pub struct Gauge {
    value: AtomicU64,
    high: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Self {
        Gauge { value: AtomicU64::new(0), high: AtomicU64::new(0) }
    }

    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
        self.high.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn high_water(&self) -> u64 {
        self.high.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.value.store(0, Ordering::Relaxed);
        self.high.store(0, Ordering::Relaxed);
    }
}

/// Fixed-bucket latency histogram (milliseconds). Bucket `i` counts
/// observations `<= bounds[i]`; one implicit overflow bucket catches the
/// rest. The running sum is kept as integer nanoseconds so concurrent
/// observers never lose fractional updates to a read-modify-write race.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Histogram {
    /// `bounds` are ascending upper edges in ms; an overflow bucket is
    /// appended implicitly.
    pub fn new(bounds: &[f64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    pub fn observe_ms(&self, ms: f64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| ms <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let ns = if ms.is_finite() && ms > 0.0 { (ms * 1e6) as u64 } else { 0 };
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 { 0.0 } else { self.sum_ms() / n as f64 }
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts, overflow bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
    }
}

/// Read-only histogram snapshot for export.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub bounds: Vec<f64>,
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum_ms: f64,
}

/// Name -> instrument maps. Instruments are leaked on first registration
/// so handles are `'static` and recording never touches the registry lock.
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, &'static Counter>,
    gauges: BTreeMap<String, &'static Gauge>,
    histograms: BTreeMap<String, &'static Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&mut self, name: &str) -> &'static Counter {
        if let Some(c) = self.counters.get(name) {
            return c;
        }
        let c: &'static Counter = Box::leak(Box::new(Counter::new()));
        self.counters.insert(name.to_string(), c);
        c
    }

    pub fn gauge(&mut self, name: &str) -> &'static Gauge {
        if let Some(g) = self.gauges.get(name) {
            return g;
        }
        let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
        self.gauges.insert(name.to_string(), g);
        g
    }

    pub fn histogram(&mut self, name: &str, bounds: &[f64]) -> &'static Histogram {
        if let Some(h) = self.histograms.get(name) {
            return h;
        }
        let h: &'static Histogram = Box::leak(Box::new(Histogram::new(bounds)));
        self.histograms.insert(name.to_string(), h);
        h
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters.get(name).map(|c| c.get()).unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> u64 {
        self.gauges.get(name).map(|g| g.get()).unwrap_or(0)
    }

    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect()
    }

    /// (name, current, high-water) triples.
    pub fn gauges(&self) -> Vec<(String, u64, u64)> {
        self.gauges
            .iter()
            .map(|(k, g)| (k.clone(), g.get(), g.high_water()))
            .collect()
    }

    pub fn histograms(&self) -> Vec<(String, HistogramSnapshot)> {
        self.histograms
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    HistogramSnapshot {
                        bounds: h.bounds().to_vec(),
                        buckets: h.bucket_counts(),
                        count: h.count(),
                        sum_ms: h.sum_ms(),
                    },
                )
            })
            .collect()
    }

    /// Zero every instrument; registered names survive (their handles are
    /// `'static` and may be cached by instrumentation sites).
    pub fn reset(&self) {
        for c in self.counters.values() {
            c.reset();
        }
        for g in self.gauges.values() {
            g.reset();
        }
        for h in self.histograms.values() {
            h.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.reset();
        assert_eq!(c.get(), 0);

        let g = Gauge::new();
        g.set(9);
        g.set(3);
        assert_eq!(g.get(), 3);
        assert_eq!(g.high_water(), 9, "high-water survives a lower set");
        g.reset();
        assert_eq!((g.get(), g.high_water()), (0, 0));
    }

    #[test]
    fn histogram_buckets_count_and_sum() {
        let h = Histogram::new(&[1.0, 10.0]);
        h.observe_ms(0.5); // bucket 0
        h.observe_ms(1.0); // bucket 0 (inclusive upper edge)
        h.observe_ms(5.0); // bucket 1
        h.observe_ms(50.0); // overflow
        assert_eq!(h.bucket_counts(), vec![2, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.sum_ms() - 56.5).abs() < 1e-6);
        assert!((h.mean_ms() - 56.5 / 4.0).abs() < 1e-6);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.bucket_counts(), vec![0, 0, 0]);
    }

    #[test]
    fn histogram_ignores_non_finite_sums() {
        let h = Histogram::new(&[1.0]);
        h.observe_ms(f64::INFINITY);
        assert_eq!(h.count(), 1, "observation still counted");
        assert_eq!(h.sum_ms(), 0.0, "non-finite value adds nothing to the sum");
    }

    #[test]
    fn registry_interns_one_instrument_per_name() {
        let mut r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        assert!(std::ptr::eq(a, b), "same name must return the same instrument");
        a.inc();
        assert_eq!(r.counter_value("x"), 1);
        assert_eq!(r.counter_value("unregistered"), 0);
        r.reset();
        assert_eq!(r.counter_value("x"), 0);
        assert!(std::ptr::eq(r.counter("x"), a), "reset keeps registrations");
    }
}
