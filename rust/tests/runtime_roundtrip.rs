//! Runtime-level integration: HLO-text loading, executable registry, buffer
//! staging, output tuple handling, and leak safety of the execute_b path.

use mimose::runtime::{lit_f32, DType, Runtime};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmRSS"))
                .and_then(|l| l.split_whitespace().nth(1).and_then(|v| v.parse().ok()))
        })
        .unwrap_or(0)
}

fn stage_all(rt: &Runtime, name: &str, seq: usize) -> Vec<xla::PjRtBuffer> {
    let meta = rt.manifest.artifact(name, seq).unwrap().clone();
    meta.inputs
        .iter()
        .map(|s| match s.dtype {
            DType::F32 => rt.stage_f32(&vec![0.01f32; s.elems()], &s.shape).unwrap(),
            DType::I32 => rt.stage_i32(&vec![1i32; s.elems()], &s.shape).unwrap(),
        })
        .collect()
}

#[test]
fn every_artifact_loads_and_executes() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(&artifacts_dir(), "bert-tiny").unwrap();
    let seq = rt.manifest.seq_buckets[0];
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|a| a.seq == seq)
        .map(|a| a.name.clone())
        .collect();
    assert_eq!(names.len(), 7, "expected 7 artifact kinds");
    for name in names {
        rt.load(&name, seq).unwrap();
        let bufs = stage_all(&rt, &name, seq);
        let out = rt
            .exec_buffers(&name, seq, &bufs.iter().collect::<Vec<_>>())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let want = rt.manifest.artifact(&name, seq).unwrap().outputs.len();
        assert_eq!(out.len(), want, "{name}: output arity");
        for lit in &out {
            assert!(lit.size_bytes() > 0);
        }
    }
}

#[test]
fn literal_exec_path_matches_buffer_path() {
    if !have_artifacts() {
        eprintln!("skipping");
        return;
    }
    let mut rt = Runtime::new(&artifacts_dir(), "bert-tiny").unwrap();
    let seq = rt.manifest.seq_buckets[0];
    rt.load("head_step", seq).unwrap();
    let meta = rt.manifest.artifact("head_step", seq).unwrap().clone();
    let lits: Vec<xla::Literal> = meta
        .inputs
        .iter()
        .map(|s| match s.dtype {
            DType::F32 => lit_f32(&vec![0.02f32; s.elems()], &s.shape).unwrap(),
            DType::I32 => {
                let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&vec![3i32; s.elems()]).reshape(&dims).unwrap()
            }
        })
        .collect();
    let a = rt.exec("head_step", seq, &lits).unwrap();
    let bufs = stage_all(&rt, "head_step", seq);
    // different inputs, so just compare arity + finiteness; exact-value
    // equivalence of the two paths is covered by using exec() (which routes
    // through exec_buffers) everywhere else
    let b = rt.exec_buffers("head_step", seq, &bufs.iter().collect::<Vec<_>>()).unwrap();
    assert_eq!(a.len(), b.len());
    assert!(a[0].get_first_element::<f32>().unwrap().is_finite());
}

#[test]
fn repeated_execution_does_not_leak() {
    if !have_artifacts() {
        eprintln!("skipping");
        return;
    }
    let mut rt = Runtime::new(&artifacts_dir(), "bert-tiny").unwrap();
    let seq = rt.manifest.seq_buckets[0];
    rt.load("block_fwd", seq).unwrap();
    // warm
    for _ in 0..5 {
        let bufs = stage_all(&rt, "block_fwd", seq);
        let _ = rt.exec_buffers("block_fwd", seq, &bufs.iter().collect::<Vec<_>>()).unwrap();
    }
    let base = rss_kb();
    for _ in 0..200 {
        let bufs = stage_all(&rt, "block_fwd", seq);
        let _ = rt.exec_buffers("block_fwd", seq, &bufs.iter().collect::<Vec<_>>()).unwrap();
    }
    let grown = rss_kb().saturating_sub(base);
    // 200 calls x ~1 MB of I/O each would leak >100 MB on the broken path
    assert!(grown < 40_000, "rss grew {grown} kB over 200 execs");
}

#[test]
fn unknown_artifact_and_bad_arity_error() {
    if !have_artifacts() {
        eprintln!("skipping");
        return;
    }
    let mut rt = Runtime::new(&artifacts_dir(), "bert-tiny").unwrap();
    let seq = rt.manifest.seq_buckets[0];
    assert!(rt.load("nope", seq).is_err());
    rt.load("embed_fwd", seq).unwrap();
    assert!(rt.exec("embed_fwd", seq, &[]).is_err());
}

#[test]
fn compile_time_recorded() {
    if !have_artifacts() {
        eprintln!("skipping");
        return;
    }
    let mut rt = Runtime::new(&artifacts_dir(), "bert-tiny").unwrap();
    let seq = rt.manifest.seq_buckets[0];
    rt.load("block_fwd", seq).unwrap();
    assert!(rt.compile_ms > 0.0);
    let after_first = rt.compile_ms;
    rt.load("block_fwd", seq).unwrap(); // cached: no recompile
    assert_eq!(rt.compile_ms, after_first);
}
